#!/usr/bin/env python
"""Benchmark regression gate (the CI ``bench-smoke`` job; ``make bench-check``).

Compares a ``BENCH_*.json`` produced by
``python -m benchmarks.kernel_micro --smoke --json BENCH_<sha>.json``
against the committed baseline (``benchmarks/bench_baseline.json``) and
exits non-zero when any tracked metric regresses by more than
``--max-regression`` (default 30%, absorbing runner noise while catching
real slowdowns — an accidental 2× would trip it many times over).

Metric direction is inferred from the key, the same naming contract
``kernel_micro`` uses throughout:

  * lower-is-better: ``*_us_per_*``, ``*_ms`` — latency keys;
  * higher-is-better: ``*_per_s*``, ``*_speedup``, ``*_hit_rate``,
    ``*_gops`` — throughput/ratio keys, cache effectiveness, and the
    LUT-matmul deployment kernel;
  * everything else (``n_runs``, ``row_kb``, the ``_meta`` block) is shape
    metadata and ignored.

Keys present on only one side are reported but never fail the gate (new
benches must be able to land before their first baseline refresh).  Refresh
the baseline deliberately with ``make bench-baseline`` after a change that
legitimately moves the numbers, and commit it — the committed trajectory of
``BENCH_*`` artifacts plus this gate is the repo's perf history.

The ``*_per_s`` keys are absolute and therefore machine-dependent: a
baseline measured on one host gates a runner class honestly only after one
refresh ON that class.  If the gate goes red on a hardware change rather
than a code change (every key shifted together, ``*_speedup`` ratios
steady), refresh the baseline from the uploaded ``BENCH_<sha>.json``
artifact of a known-good commit on the new runner class and commit that —
or widen the gate once via the ``BENCH_MAX_REGRESSION`` env var while the
refresh lands.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "bench_baseline.json")

# keys that look numeric but are workload shape, not performance
IGNORED = {"n_runs", "row_kb"}


def flatten(tree: dict, prefix: str = "") -> dict[str, float]:
    """``{"sweep": {"x_per_s": 1.0}} -> {"sweep.x_per_s": 1.0}`` (numeric
    leaves only; the ``_meta`` block and shape keys are dropped)."""
    out: dict[str, float] = {}
    for key, val in tree.items():
        if key == "_meta" or key in IGNORED:
            continue
        name = f"{prefix}{key}"
        if isinstance(val, dict):
            out.update(flatten(val, name + "."))
        elif isinstance(val, (int, float)) and not isinstance(val, bool):
            out[name] = float(val)
    return out


def direction(key: str) -> str | None:
    """'up' (higher better), 'down' (lower better) or None (untracked)."""
    leaf = key.rsplit(".", 1)[-1]
    if "_us_per_" in leaf or leaf.endswith("_ms"):
        return "down"
    if ("_per_s" in leaf or leaf.endswith("_speedup")
            or leaf.endswith("_hit_rate") or leaf.endswith("_gops")):
        return "up"
    return None


def compare(current: dict, baseline: dict, max_regression: float
            ) -> tuple[list[str], list[str]]:
    """Returns (failures, report_lines)."""
    cur, base = flatten(current), flatten(baseline)
    failures, lines = [], []
    for key in sorted(set(cur) | set(base)):
        d = direction(key)
        if d is None:
            continue
        if key not in base:
            lines.append(f"  NEW  {key} = {cur[key]:.4g} (no baseline)")
            continue
        if key not in cur:
            lines.append(f"  GONE {key} (baseline {base[key]:.4g}; "
                         f"not failing — refresh the baseline)")
            continue
        b, c = base[key], cur[key]
        if b <= 0:
            continue
        change = (c - b) / b if d == "down" else (b - c) / b
        mark = "ok"
        if change > max_regression:
            mark = "FAIL"
            failures.append(
                f"{key}: {'slower' if d == 'down' else 'dropped'} "
                f"{100 * change:.1f}% (baseline {b:.4g} -> {c:.4g}, "
                f"limit {100 * max_regression:.0f}%)")
        lines.append(f"  {mark:4s} {key}: {b:.4g} -> {c:.4g} "
                     f"({'+' if change <= 0 else '-'}"
                     f"{100 * abs(change):.1f}% vs limit "
                     f"{100 * max_regression:.0f}%)")
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="BENCH_*.json written by "
                                    "benchmarks.kernel_micro --json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline (default: "
                         "benchmarks/bench_baseline.json)")
    ap.add_argument("--max-regression", type=float,
                    default=float(os.environ.get("BENCH_MAX_REGRESSION",
                                                 "0.30")),
                    help="fail above this fractional regression (default "
                         "0.30, or the BENCH_MAX_REGRESSION env var)")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if not os.path.exists(args.baseline):
        print(f"check_bench: no baseline at {args.baseline} — nothing to "
              f"gate (commit one with `make bench-baseline`)")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures, lines = compare(current, baseline, args.max_regression)
    print(f"check_bench: {args.current} vs {args.baseline}")
    for line in lines:
        print(line)
    if failures:
        print(f"check_bench: {len(failures)} regression(s) past the "
              f"{100 * args.max_regression:.0f}% gate:")
        for fail in failures:
            print(f"FAIL {fail}")
        return 1
    print("check_bench OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

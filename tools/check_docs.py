#!/usr/bin/env python
"""Docs/link check (the CI `docs` leg; `make docs-check`).

Verifies the documentation surface stays truthful:

  1. every relative markdown link in README/DESIGN/ROADMAP/CHANGES points at
     an existing file (and an existing heading, for #anchors);
  2. every ``DESIGN.md §N[.M]`` reference in the source tree resolves to a
     section marker actually present in DESIGN.md, and DESIGN.md itself
     still carries every required top-level section marker (§1–§6);
  3. every documented command is runnable at ``--help`` level: the ROADMAP
     tier-1 command plus each ``python ...`` command found in README.md /
     DESIGN.md / ROADMAP.md — inline backticks AND fenced ```…``` blocks
     (module/script resolved, args replaced by ``--help``) — plus the
     explicit entry-point list below;
  4. every long ``--flag`` a documented command passes actually exists in
     that command's ``--help`` output (a doc snippet naming a flag the CLI
     dropped — or never grew — fails here).

Exit code 0 == all good; failures are listed one per line.
"""
from __future__ import annotations

import os
import re
import shlex
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "DESIGN.md", "ROADMAP.md", "CHANGES.md", "PAPER.md"]
COMMAND_DOCS = ["README.md", "DESIGN.md", "ROADMAP.md"]
SOURCE_DIRS = ["src", "benchmarks", "examples", "tests", "tools"]

# top-level DESIGN.md sections that must exist (docstring references point
# into these; §6 is the multi-host sweep surface, §7 the kernel-layout /
# tuning surface, §8 the phenotype-dedup evaluation cache, §9 the sampled
# evaluation mode, §10 the exact-verification escalation tier, §11 the
# async commit pipeline + island migration, §12 the circuit-artifact
# registry and the evolve → LUT → serve deployment path)
REQUIRED_DESIGN_SECTIONS = ["§1", "§2", "§3", "§4", "§5", "§6", "§7", "§8",
                            "§9", "§10", "§11", "§12"]

# argparse-bearing entry points that must answer --help (quickstart.py is
# deliberately absent: it has no CLI and would run the full search)
ENTRY_POINTS = [
    [sys.executable, "-m", "repro.launch.evolve", "--help"],
    [sys.executable, "-m", "repro.launch.train", "--help"],
    [sys.executable, "-m", "repro.launch.serve", "--help"],
    [sys.executable, "-m", "repro.launch.dryrun", "--help"],
    [sys.executable, "-m", "repro.launch.roofline", "--help"],
    [sys.executable, "-m", "repro.launch.export", "--help"],
    [sys.executable, "-m", "benchmarks.run", "--help"],
    [sys.executable, "-m", "benchmarks.kernel_micro", "--help"],
    [sys.executable, "examples/pareto_sweep.py", "--help"],
    [sys.executable, "examples/approx_nn_inference.py", "--help"],
    [sys.executable, "examples/train_lm.py", "--help"],
    [sys.executable, "tools/check_bench.py", "--help"],
    [sys.executable, "-m", "pytest", "--help"],
]

# flags that must exist in specific --help outputs even when no doc snippet
# happens to pass them (the layout/tuning surface of DESIGN.md §7)
REQUIRED_FLAGS = {
    ("-m", "repro.launch.evolve"): ["--layout", "--backend", "--dedup",
                                    "--dedup-cache-size", "--eval-mode",
                                    "--sample-size", "--input-dist",
                                    "--certify", "--certify-budget",
                                    "--async-commit", "--migrate-every",
                                    "--migrate-timeout",
                                    "--export-artifacts"],
    ("-m", "repro.launch.serve"): ["--approx-lut", "--summary-out"],
    ("-m", "repro.launch.export"): ["--results-dir", "--out", "--top-k",
                                    "--require-certified", "--verify"],
    ("-m", "benchmarks.kernel_micro"): ["--layout", "--tune", "--json",
                                        "--smoke"],
    ("tools/check_bench.py",): ["--baseline", "--max-regression"],
}

# documented scripts that must NOT be --help-probed (no argparse: running
# them executes the real workload)
SKIP_HELP = {"examples/quickstart.py"}

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SECREF = re.compile(r"DESIGN\.md\s*§(\d+(?:\.\d+)?)")
_CMD = re.compile(r"`((?:[A-Z_][A-Z0-9_]*=\S*\s+)*(?:PYTHONPATH=\S+\s+)?"
                  r"python[^`]*)`")
_FENCE = re.compile(r"^```")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\s-]", "", s)
    return re.sub(r"\s+", "-", s)


def check_links() -> list[str]:
    errors = []
    headings = {}
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            text = f.read()
        headings[doc] = {_slug(m.group(1)) for m in
                         re.finditer(r"^#+\s+(.+)$", text, re.M)}
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            text = f.read()
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            fname, _, anchor = target.partition("#")
            if fname and not os.path.exists(os.path.join(ROOT, fname)):
                errors.append(f"{doc}: broken link -> {target}")
                continue
            if anchor:
                owner = fname or doc
                known = headings.get(owner)
                if known is not None and anchor not in known:
                    errors.append(f"{doc}: broken anchor -> {target}")
    return errors


def check_design_sections() -> list[str]:
    path = os.path.join(ROOT, "DESIGN.md")
    if not os.path.exists(path):
        return ["DESIGN.md missing"]
    with open(path) as f:
        design = f.read()
    errors = [f"DESIGN.md: required section marker {sec} missing"
              for sec in REQUIRED_DESIGN_SECTIONS
              if not re.search(rf"^##\s+{sec}\b", design, re.M)]
    for base in SOURCE_DIRS + ["."]:
        root = os.path.join(ROOT, base)
        for dirpath, _, files in os.walk(root):
            if base == "." and dirpath != root:
                continue  # top level: only the .md files themselves
            for name in files:
                if not name.endswith((".py", ".md")):
                    continue
                fpath = os.path.join(dirpath, name)
                with open(fpath, errors="replace") as f:
                    text = f.read()
                for m in _SECREF.finditer(text):
                    if f"§{m.group(1)}" not in design:
                        rel = os.path.relpath(fpath, ROOT)
                        errors.append(f"{rel}: dangling DESIGN.md "
                                      f"§{m.group(1)} reference")
    return sorted(set(errors))


def _tokens(cmd: str) -> list[str]:
    """Shell-split a documented command: joined continuations, trailing
    ``# comments`` dropped, env assignments stripped."""
    try:
        tokens = shlex.split(cmd.replace("\\\n", " "), comments=True)
    except ValueError:
        return []
    return [t for t in tokens if "=" not in t or not
            re.match(r"^[A-Z_][A-Z0-9_]*=", t)]  # strip env assignments


def _help_variant(tokens: list[str]) -> list[str] | None:
    """Rewrite a documented command into its --help invocation: keep the
    interpreter and the module/script target, drop everything else."""
    if not tokens or not tokens[0].startswith("python"):
        return None
    out = [sys.executable]
    rest = tokens[1:]
    if rest[:1] == ["-m"] and len(rest) > 1:
        out += ["-m", rest[1]]
    else:
        script = next((t for t in rest if t.endswith(".py")), None)
        if script is None or script in SKIP_HELP:
            return None
        out.append(script)
    return out + ["--help"]


def _doc_flags(tokens: list[str]) -> set[str]:
    """The long ``--flag`` options a documented command passes (values and
    bracketed optional spellings like ``[--backend ...]`` excluded)."""
    return {t.split("=")[0] for t in tokens[1:]
            if t.startswith("--") and len(t) > 2}


def _iter_doc_commands(text: str):
    """Yield candidate command strings: inline backticked ``python ...``
    commands plus every ``python``-leading line (backslash continuations
    joined) inside fenced code blocks."""
    for m in _CMD.finditer(text):
        yield m.group(1)
    in_fence, buf = False, ""
    for line in text.splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            buf = ""
            continue
        if not in_fence:
            continue
        buf = buf + " " + line.strip() if buf else line.strip()
        if buf.endswith("\\"):
            buf = buf[:-1].strip()
            continue
        if re.match(r"^(?:[A-Z_][A-Z0-9_]*=\S+\s+)*python(\s|$)", buf):
            yield buf
        buf = ""


def check_commands() -> list[str]:
    """Every documented command answers --help, and every long flag it is
    documented with exists in that --help output."""
    cmds: dict[tuple, set[str]] = {tuple(c): set() for c in ENTRY_POINTS}
    for target, flags in REQUIRED_FLAGS.items():
        cmds.setdefault((sys.executable, *target, "--help"),
                        set()).update(flags)
    for doc in COMMAND_DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            text = f.read()
        for cmd in _iter_doc_commands(text):
            tokens = _tokens(cmd)
            variant = _help_variant(tokens)
            if variant:
                cmds.setdefault(tuple(variant), set()).update(
                    _doc_flags(tokens))
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    errors = []
    for cmd, flags in sorted(cmds.items()):
        proc = subprocess.run(list(cmd), cwd=ROOT, env=env,
                              capture_output=True, timeout=300)
        if proc.returncode != 0:
            tail = proc.stderr.decode(errors="replace").strip()[-200:]
            errors.append(f"--help failed ({proc.returncode}): "
                          f"{' '.join(cmd[1:])}: {tail}")
            continue
        helptext = proc.stdout.decode(errors="replace")
        for flag in sorted(flags - {"--help"}):
            # word boundary: a documented "--pod" must not pass because
            # "--pod-index" exists
            if not re.search(re.escape(flag) + r"(?![\w-])", helptext):
                errors.append(f"documented flag {flag} not in "
                              f"{' '.join(cmd[1:-1])} --help")
    return errors


def main() -> int:
    errors = check_links() + check_design_sections() + check_commands()
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print("docs check OK (links, DESIGN sections, --help commands)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

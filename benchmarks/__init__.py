"""Benchmarks package — makes every benchmark module-invocable
(``python -m benchmarks.kernel_micro`` / ``python -m benchmarks.run``) so CI,
the Makefile and the docs all use one entry-point spelling regardless of CWD.
Each module adds ``src/`` to ``sys.path`` relative to its own file, so plain
script invocation from any directory works too.
"""

"""Kernel microbenchmarks: fused sim+metrics throughput (the paper's hot
loop), the unfused baseline, the batched constraint-grid sweep engine
vs the serial per-run loop (with a backend × Pallas-layout axis), and the
streaming results layer (shard spill + read-back rows/s), on this host
(CPU: jnp path; the Pallas kernel is timed in interpret mode only for
reference — its target is TPU, and interpret mode hides the HBM cube
traffic the cube-major layout removes).

Script / module mode (CWD-independent):
  python -m benchmarks.kernel_micro \
      [--only eval,gen,pallas,sweep,results,certify,lut]
      [--backend jnp,pallas] [--layout genome_major,cube_major]
      [--smoke] [--json BENCH_out.json]

``--smoke`` shrinks every budget to the CI bench-gate size (the
``bench-smoke`` job / ``make bench-check``); ``--json`` writes the metric
dict consumed by ``tools/check_bench.py``.  ``--tune`` runs the measured
kernel-layout autotune pass instead of the benches and refreshes the
tuning table behind ``layout="auto"`` (``repro.kernels.tune``).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golden as G, metrics as M, simulate as S
from repro.core.genome import random_genome
from repro.kernels import ops, ref


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_eval_throughput(width: int = 8, lam: int = 8):
    """Candidate-evaluations/s: fused (single pass, what the TPU kernel
    does) vs unfused (sim -> unpack -> 7 metric passes)."""
    gold, spec = G.array_multiplier(width, n_n=400)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    genomes = jax.vmap(lambda k: random_genome(k, spec))(
        jax.random.split(jax.random.PRNGKey(0), lam))

    @jax.jit
    def fused(gs):
        return jax.vmap(
            lambda g: ref.cgp_eval_ref(g, spec, planes, gvals, 256.0))(gs)

    @jax.jit
    def unfused(gs):
        def one(g):
            vals = S.simulate_values(g, spec, planes)       # pass 1
            met = M.metrics_from_values(gvals, vals, spec.n_o)  # pass 2
            wires = S.simulate_planes(g, spec, planes)      # re-sim for p
            p = S.signal_probabilities(wires[spec.n_i:])
            return met, p
        return jax.vmap(one)(gs)

    t_f = _time(fused, genomes)
    t_u = _time(unfused, genomes)
    evals = lam
    return {
        "fused_us_per_eval": 1e6 * t_f / evals,
        "unfused_us_per_eval": 1e6 * t_u / evals,
        "fused_speedup": t_u / t_f,
        "inputs_per_s_fused": evals * spec.n_inputs_total / t_f,
    }


def bench_pallas_interpret(width: int = 6):
    """Interpret-mode cost of the Pallas kernel (correctness path only —
    the performance target is the TPU lowering)."""
    gold, spec = G.array_multiplier(width, n_n=250)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    t_k = _time(lambda: ops.cgp_eval(gold, spec, planes, gvals), reps=3)
    t_r = _time(lambda: ref.cgp_eval_ref(gold, spec, planes, gvals, 256.0),
                reps=3)
    return {"pallas_interpret_ms": 1e3 * t_k, "jnp_ref_ms": 1e3 * t_r}


def bench_generation_rate(width: int = 8, gens: int = 100, lam: int = 8,
                          n_n: int = 400):
    """End-to-end (1+λ) generations/s — the paper's search-speed metric."""
    from repro.core.evolve import EvolveConfig, evolve
    from repro.core.fitness import ConstraintSpec
    from repro.core.search import SearchConfig, problem_arrays
    cfg = SearchConfig(width=width, n_n=n_n,
                       evolve=EvolveConfig(generations=gens, lam=lam))
    gold, spec, planes, gvals, gpower = problem_arrays(cfg)
    thr = jnp.asarray(ConstraintSpec(mae=1.0).thresholds())

    def run(seed):
        return evolve(spec, cfg.evolve, gold, thr, planes, gvals, gpower,
                      jax.random.PRNGKey(seed)).best_fit

    run(0)  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(run(1))
    dt = time.perf_counter() - t0
    return {"generations_per_s": gens / dt,
            "evals_per_s": gens * lam / dt,
            "exhaustive_inputs_per_s": gens * lam * spec.n_inputs_total / dt}


def bench_sweep(width: int = 3, gens: int = 200, lam: int = 4,
                n_seeds: int = 2, backends: tuple = ("jnp", "pallas"),
                layouts: tuple = ("genome_major", "cube_major"),
                dedup_width: int = 6, dedup_gens: int = 60,
                dedup_n_n: int = 300, dedup_mutation_rate: float = 0.0005,
                sampled_width: int = 12, sampled_gens: int = 20,
                sampled_size: int = 1 << 13):
    """Constraint-grid throughput (runs/s): batched engine vs serial loop,
    with a ``backend`` axis over the candidate-evaluation path and — for
    the pallas backend — a ``layout`` axis over the evaluation-grid order
    (genome-major vs the transposed cube-major grid, DESIGN.md §7).

    The grid is 6 constraint configs × ``n_seeds`` seeds; all paths are
    compiled before timing, so the ratios isolate execution throughput (the
    batched engine additionally saves one trace per seed on the cold path).
    The "pallas" legs drive the fused (runs × λ) kernel — on CPU it runs in
    interpret mode, so their runs/s are correctness-path references; the
    jnp-vs-pallas and layout gaps worth tracking are on a TPU backend
    (interpret mode hides the HBM reuse cube-major buys).

    The ``dedup_*`` legs time the phenotype-dedup cache (DESIGN.md §8) on a
    deliberately neutral-mutation-heavy grid — wide cube, big genome, low
    mutation rate, the regime where most offspring share an active subgraph
    with their parent and the cache's skipped kernel dispatches dominate its
    host-side hashing cost.  Emits cached vs uncached effective runs/s and
    the measured cache hit rate.

    The ``sampled_*`` leg times ``eval_mode="sampled"`` (DESIGN.md §9) past
    the exhaustive wall: a width-``sampled_width`` multiplier grid whose
    2^(2w) cube (16.7M rows at width 12) no evolve loop could afford,
    evaluated on a ``sampled_size``-row uniform sample instead.  Runs on the
    jnp backend — the Pallas byte-split ``_exact_sum`` regime is not exact
    at n_o = 24 (DESIGN.md §9).  Emits ``sampled_runs_per_s``, the key the
    bench gate tracks.

    The ``async_commit`` leg times the same grid streamed through the
    results layer chunk-by-chunk (history kept, so the shard payload is
    real), sync vs ``async_commit=True`` (DESIGN.md §11) into fresh temp
    dirs.  ``async_commit_speedup`` is the ratio the gate tracks; on a
    CPU-bound smoke box the overlap window is thin, so ~1.0 is expected —
    the key mostly guards against the committer *adding* overhead.
    """
    import dataclasses

    from repro.core.evolve import EvolveConfig
    from repro.core.fitness import ConstraintSpec
    from repro.core.search import SearchConfig, run_search, run_sweep_serial
    from repro.core.sweep import SweepConfig, run_sweep_batched

    cfg = SearchConfig(width=width, n_n=100,
                       evolve=EvolveConfig(generations=gens, lam=lam))
    cons = ([ConstraintSpec(mae=t) for t in (0.3, 0.6, 1.0, 2.0)]
            + [ConstraintSpec(er=e) for e in (30.0, 60.0)])
    seeds = tuple(range(n_seeds))
    n_runs = len(cons) * len(seeds)
    sweep = SweepConfig(chunk_size=n_runs, keep_history=False)

    run_search(cfg, cons[0], 0)                       # compile serial path
    t0 = time.perf_counter()
    run_sweep_serial(cfg, cons, seeds)
    t_serial = time.perf_counter() - t0
    out = {"n_runs": n_runs, "serial_runs_per_s": n_runs / t_serial}

    def one(backend, layout=None, tag=None):
        cfg_b = dataclasses.replace(
            cfg, evolve=dataclasses.replace(cfg.evolve, backend=backend))
        sweep_b = sweep if layout is None else dataclasses.replace(
            sweep, layout=layout)
        run_sweep_batched(cfg_b, cons, seeds, sweep_b)  # compile
        t0 = time.perf_counter()
        run_sweep_batched(cfg_b, cons, seeds, sweep_b)
        t_b = time.perf_counter() - t0
        tag = tag or backend
        out[f"batched_{tag}_runs_per_s"] = n_runs / t_b
        out[f"batched_{tag}_speedup"] = t_serial / t_b

    for backend in backends:
        if backend == "pallas":
            for layout in layouts:  # layout is a no-op on the jnp path
                one(backend, layout, tag=f"pallas_{layout}")
        else:
            one(backend)

    # --- phenotype-dedup legs (DESIGN.md §8): neutral-mutation-heavy grid --
    dcfg = SearchConfig(
        width=dedup_width, n_n=dedup_n_n,
        evolve=EvolveConfig(generations=dedup_gens, lam=lam,
                            mutation_rate=dedup_mutation_rate,
                            backend=backends[0]))
    dcons = cons[:4]  # one σ group (shared default σ): one trace per leg
    dn = len(dcons) * len(seeds)
    for tag, on in (("dedup_off", False), ("dedup", True)):
        sw = SweepConfig(chunk_size=dn, keep_history=False, dedup=on)
        run_sweep_batched(dcfg, dcons, seeds, sw)  # compile
        t0 = time.perf_counter()
        res = run_sweep_batched(dcfg, dcons, seeds, sw)
        t_d = time.perf_counter() - t0
        out[f"{tag}_runs_per_s"] = dn / t_d
        if on:
            out["dedup_speedup"] = (out["dedup_runs_per_s"]
                                    / out["dedup_off_runs_per_s"])
            out["dedup_hit_rate"] = res.dedup_stats["hit_rate"]

    # --- sampled-eval leg (DESIGN.md §9): width past the exhaustive wall --
    _, spec_s = G.array_multiplier(sampled_width, n_n=None)  # auto-sized
    scfg = SearchConfig(
        width=sampled_width, kind="mul", n_n=spec_s.n_n,
        evolve=EvolveConfig(generations=sampled_gens, lam=lam,
                            eval_mode="sampled", sample_size=sampled_size,
                            input_dist="uniform"))
    scons = cons[:2]  # one σ group: one trace for the leg
    sn = len(scons) * len(seeds)
    ssw = SweepConfig(chunk_size=sn, keep_history=False)
    run_sweep_batched(scfg, scons, seeds, ssw)  # compile
    t0 = time.perf_counter()
    run_sweep_batched(scfg, scons, seeds, ssw)
    t_s = time.perf_counter() - t0
    out["sampled_runs_per_s"] = sn / t_s
    out["sampled_inputs_per_s"] = (sn * sampled_gens * lam
                                   * sampled_size / t_s)

    # --- async-commit leg (DESIGN.md §11): overlap shard commits with the
    # next chunk's evaluation; fresh temp dir per timed run so every commit
    # is a real write (a reused dir would skip committed spans on resume)
    import shutil
    import tempfile

    acfg = dataclasses.replace(
        cfg, evolve=dataclasses.replace(cfg.evolve, backend=backends[0]))

    def one_commit_run(async_on):
        d = tempfile.mkdtemp(prefix="bench_async_commit_")
        try:
            sw = SweepConfig(chunk_size=max(2, n_runs // 4),
                             keep_history=True, results_dir=d,
                             async_commit=async_on)
            t0 = time.perf_counter()
            run_sweep_batched(acfg, cons, seeds, sw)
            return time.perf_counter() - t0
        finally:
            shutil.rmtree(d, ignore_errors=True)

    one_commit_run(False)  # compile the chunked trace
    t_syncc = one_commit_run(False)
    t_asyncc = one_commit_run(True)
    out["async_commit_runs_per_s"] = n_runs / t_asyncc
    out["async_commit_speedup"] = t_syncc / t_asyncc
    return out


def bench_results(n_runs: int = 2048, gens: int = 256, chunk: int = 128,
                  n_n: int = 100, n_o: int = 8):
    """Streaming results layer: shard spill and read-back rows/s.

    Synthetic run-major buffers at realistic shapes (the per-row payload is
    dominated by ``hist_metrics``: gens × N_METRICS floats) are committed
    chunk-by-chunk through ``SweepResultWriter`` and drained back through
    ``SweepResultReader`` — the host-side path that bounds paper-scale grids
    now that the fused kernel owns the evaluation side.  "summary" read-back
    is the figure-pipeline path (correlations + fronts from grid-order
    summary columns); "history" read-back drains every history shard at
    one-chunk peak memory.
    """
    import tempfile

    from repro.core import metrics as M
    from repro.core.results import SweepResultReader, SweepResultWriter

    rng = np.random.default_rng(0)
    rows_all = {
        "grid_rows": np.arange(n_runs, dtype=np.int32),
        "thresholds": rng.random((n_runs, M.N_METRICS), np.float32),
        "parent_nodes": rng.integers(0, 99, (n_runs, n_n, 3), np.int32),
        "parent_outs": rng.integers(0, 99, (n_runs, n_o), np.int32),
        "best_nodes": rng.integers(0, 99, (n_runs, n_n, 3), np.int32),
        "best_outs": rng.integers(0, 99, (n_runs, n_o), np.int32),
        "best_fit": rng.random(n_runs, np.float32),
        "metrics": rng.random((n_runs, M.N_METRICS), np.float32),
        "metrics_stderr": rng.random((n_runs, M.N_METRICS), np.float32),
        "power_rel": rng.random(n_runs, np.float32),
        "feasible": rng.integers(0, 2, n_runs, np.uint8),
        "certified_mask": rng.integers(0, 2, n_runs, np.uint8),
        "error_mean": rng.random(n_runs, np.float32),
        "error_std": rng.random(n_runs, np.float32),
        "hist_power_rel": rng.random((n_runs, gens), np.float32),
        "hist_fit": rng.random((n_runs, gens), np.float32),
        "hist_metrics": rng.random((n_runs, gens, M.N_METRICS), np.float32),
    }
    grid_meta = [{"constraint": f"mae<={i % 7}%", "seed": i,
                  "gauss_sigma": 256.0} for i in range(n_runs)]
    with tempfile.TemporaryDirectory() as d:
        writer = SweepResultWriter(
            d, grid_fingerprint="bench", grid_meta=grid_meta, n_runs=n_runs,
            gens=gens, n_n=n_n, n_o=n_o, keep_history="summary",
            chunk_size=chunk)
        t0 = time.perf_counter()
        for start in range(0, n_runs, chunk):
            end = min(start + chunk, n_runs)
            writer.write_chunk(
                (start, end),
                {k: v[start:end] for k, v in rows_all.items()})
        t_spill = time.perf_counter() - t0

        reader = SweepResultReader(d)
        t0 = time.perf_counter()
        reader.correlations()
        reader.fronts()
        t_summary = time.perf_counter() - t0
        t0 = time.perf_counter()
        drained = 0
        for rows, hist in reader.iter_history():
            drained += hist["hist_metrics"].shape[0]
        t_hist = time.perf_counter() - t0
        assert drained == n_runs

    row_bytes = sum(v.nbytes for v in rows_all.values()) / n_runs
    return {
        "spill_rows_per_s": n_runs / t_spill,
        "spill_mb_per_s": n_runs * row_bytes / t_spill / 2**20,
        "summary_readback_rows_per_s": n_runs / t_summary,
        "history_readback_rows_per_s": n_runs / t_hist,
        "row_kb": row_bytes / 1024,
    }


def bench_certify(width: int = 8, n_elites: int = 6, rate: float = 0.02,
                  chunk_rows: int = 8192):
    """Exact-verification escalation throughput (DESIGN.md §10).

    Times ``certify.certified_metrics`` over mutated elites of the exact
    golden netlist — the per-elite cost the sweep's escalation driver pays
    when a sampled-feasible candidate is promoted to the exact tier.  Both
    regimes at the same width so the numbers are comparable: the full-cube
    dispatch (one jit'd pass over the whole 2^(2w) cube) and the chunked
    bit-parallel pass forced via a small ``dispatch_rows`` budget (the
    large-width path).
    """
    from repro.core import certify
    from repro.core.mutate import mutate_population

    gold, spec = G.array_multiplier(width, n_n=None)
    pop = mutate_population(jax.random.PRNGKey(0), gold, spec, n_elites,
                            rate)
    nodes, outs = np.asarray(pop.nodes), np.asarray(pop.outs)

    def run_all(dispatch_rows):
        t0 = time.perf_counter()
        for i in range(n_elites):
            certify.certified_metrics(nodes[i], outs[i], spec, "mul", width,
                                      256.0, dispatch_rows=dispatch_rows)
        return time.perf_counter() - t0

    for rows in (certify.DISPATCH_ROWS, chunk_rows):
        certify.certified_metrics(nodes[0], outs[0], spec, "mul", width,
                                  256.0, dispatch_rows=rows)  # compile
    t_full = run_all(certify.DISPATCH_ROWS)
    t_chunked = run_all(chunk_rows)
    return {
        "certify_escalations_per_s": n_elites / t_full,
        "certify_rows_per_s": n_elites * (1 << spec.n_i) / t_full,
        "certify_chunked_escalations_per_s": n_elites / t_chunked,
    }


def bench_lut(m: int = 256, n: int = 256, k: int = 256,
              serve_requests: int = 3, serve_prompt: int = 16,
              serve_gen: int = 8, reps: int = 3):
    """The deployment bridge (DESIGN.md §12): LUT-matmul + approx serving.

    ``lut_matmul_gops`` times the padded Pallas kernel path
    (``kernels.ops.lut_matmul``; interpret mode on CPU — like the ``pallas``
    leg, a reference number, not the TPU story) and ``lut_ref_gops_info``
    the jnp gather oracle (what CPU serving actually dispatches to); both
    count 2·M·N·K ops.  The oracle key carries the ``_info`` suffix so
    ``check_bench`` reports it without gating it: XLA's CPU gather timing
    swings several-× with machine state, and the serving path it feeds is
    already gated end to end by ``serve_approx_tokens_per_s`` — the
    continuous-batching serve loop on a reduced arch with every projection
    matmul routed through an approximate LUT.
    """
    rng = np.random.default_rng(0)
    lut = (np.arange(256)[:, None] * np.arange(256)[None, :]
           + rng.integers(-2, 3, (256, 256))).astype(np.int32)  # approx LUT
    a = jnp.asarray(rng.integers(0, 256, (m, k)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, (k, n)), jnp.int32)
    lj = jnp.asarray(lut)
    gops = 2.0 * m * n * k / 1e9
    t_kernel = _time(lambda: ops.lut_matmul(a, b, lj), reps=reps)
    t_ref = _time(lambda: ref.lut_matmul_ref(a, b, lj), reps=reps)

    from repro.launch.serve import serve
    sv = serve("llama3_2_1b", n_requests=serve_requests,
               prompt_len=serve_prompt, gen_len=serve_gen, slots=2,
               reduced=True, approx_lut=lut)
    return {
        "lut_matmul_gops": gops / t_kernel,
        "lut_ref_gops_info": gops / t_ref,
        "serve_approx_tokens_per_s": sv["tok_per_s"],
    }


# --smoke budget overrides per bench: the CI bench-gate size (seconds, not
# minutes, per bench; small enough for every push, big enough to time)
SMOKE = {
    "eval": dict(width=6, lam=4),
    "gen": dict(width=6, gens=40, lam=4, n_n=200),
    "pallas": dict(width=5),
    "sweep": dict(width=2, gens=100, n_seeds=1,
                  dedup_width=6, dedup_gens=30, dedup_n_n=300,
                  sampled_gens=5, sampled_size=2048),
    "results": dict(n_runs=512, gens=128, chunk=64),
    "certify": dict(width=6, n_elites=4, chunk_rows=1024),
    "lut": dict(m=128, n=128, k=128, serve_requests=2, serve_prompt=8,
                serve_gen=4, reps=6),
}


def run_tune(widths, runs, reps, n_n=400, table=None):
    """``--tune`` mode: measured autotune pass over a (width × R) grid —
    emits/refreshes the tuning table behind ``layout="auto"``.

    ``n_n`` defaults to the paper/production genome size: table keys carry
    only (width, R, backend), so entries must be measured at the node count
    they will decide for — the genome-block re-fetch cost cube-major pays
    scales with n_n (DESIGN.md §7.1), and a small-genome winner could pin
    the losing layout for 400-node sweeps.
    """
    from repro.kernels import tune

    def time_fn(fn, reps):  # the bench harness timer, per the tune contract
        return _time(fn, reps=reps)

    path = table or tune.table_path()
    for width in widths:
        for R in runs:
            entry = tune.autotune(width, R, n_n=n_n, reps=reps, path=path,
                                  time_fn=time_fn)
            secs = ", ".join(f"{k}={v:.4g}s" for k, v in
                             entry["seconds"].items())
            print(f"[tune] w{width} R{R} {entry['backend']}: winner "
                  f"{entry['layout']}/bw{entry['block_words']}"
                  f"/rt{entry['r_tile']}  ({secs})", flush=True)
    print(f"[tune] table -> {path}", flush=True)


def main(argv=None):
    import argparse
    import functools
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: "
                         "eval,gen,pallas,sweep,results,certify,lut")
    ap.add_argument("--backend", default="jnp,pallas",
                    help="comma list of sweep-engine backends to time "
                         "(--only sweep axis; default: jnp,pallas)")
    ap.add_argument("--layout", default="genome_major,cube_major",
                    help="comma list of Pallas evaluation-grid layouts for "
                         "the pallas sweep legs (default: both; DESIGN.md "
                         "section 7)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI budget for every bench (the bench-smoke "
                         "job / make bench-check)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (the BENCH_<sha>.json "
                         "consumed by tools/check_bench.py)")
    ap.add_argument("--tune", action="store_true",
                    help="run the measured kernel-layout autotune pass "
                         "instead of the benches (repro.kernels.tune; "
                         "refreshes the table behind layout='auto')")
    ap.add_argument("--tune-widths", default="2,4",
                    help="--tune: comma list of circuit widths")
    ap.add_argument("--tune-runs", default="8,32",
                    help="--tune: comma list of population sizes R")
    ap.add_argument("--tune-reps", type=int, default=3,
                    help="--tune: timed reps per variant")
    ap.add_argument("--tune-nodes", type=int, default=400,
                    help="--tune: genome node count to measure at (keep the "
                         "production shape: table keys omit n_n)")
    ap.add_argument("--tune-table", default=None,
                    help="--tune: tuning-table path override "
                         "(default: REPRO_TUNE_TABLE or the repo table)")
    args = ap.parse_args(argv)
    if args.tune:
        run_tune([int(w) for w in args.tune_widths.split(",") if w],
                 [int(r) for r in args.tune_runs.split(",") if r],
                 args.tune_reps, args.tune_nodes, args.tune_table)
        return
    only = set(args.only.split(",")) if args.only else None
    backends = tuple(b for b in args.backend.split(",") if b)
    if unknown := set(backends) - {"jnp", "pallas"}:
        ap.error(f"unknown backend(s): {sorted(unknown)}")
    layouts = tuple(l for l in args.layout.split(",") if l)
    if unknown := set(layouts) - {"genome_major", "cube_major"}:
        ap.error(f"unknown layout(s): {sorted(unknown)}")
    benches = {"eval": bench_eval_throughput, "gen": bench_generation_rate,
               "pallas": bench_pallas_interpret,
               "sweep": functools.partial(bench_sweep, backends=backends,
                                          layouts=layouts),
               "results": bench_results,
               "certify": bench_certify,
               "lut": bench_lut}
    if only is not None and (unknown := only - set(benches)):
        ap.error(f"unknown bench name(s): {sorted(unknown)} "
                 f"(choose from {sorted(benches)})")
    results = {}
    for name, fn in benches.items():
        if only is not None and name not in only:
            continue
        if args.smoke:
            fn = functools.partial(fn, **SMOKE[name])
        out = fn()
        results[name] = out
        parts = ", ".join(f"{k}={v:.4g}" for k, v in out.items())
        print(f"[{name}] {parts}", flush=True)
    if args.json:
        results["_meta"] = {"smoke": args.smoke, "backends": list(backends),
                            "layouts": list(layouts)}
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        print(f"[json] -> {args.json}", flush=True)


if __name__ == "__main__":
    main()

"""Kernel microbenchmarks: fused sim+metrics throughput (the paper's hot
loop), the unfused baseline, the batched constraint-grid sweep engine
vs the serial per-run loop, and the streaming results layer (shard spill +
read-back rows/s), on this host (CPU: jnp path; the Pallas kernel is timed
in interpret mode only for reference — its target is TPU).

Script mode:
  python benchmarks/kernel_micro.py [--only eval,gen,pallas,sweep,results]
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golden as G, metrics as M, simulate as S
from repro.core.genome import random_genome
from repro.kernels import ops, ref


def _time(fn, *args, reps=5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_eval_throughput(width: int = 8, lam: int = 8):
    """Candidate-evaluations/s: fused (single pass, what the TPU kernel
    does) vs unfused (sim -> unpack -> 7 metric passes)."""
    gold, spec = G.array_multiplier(width, n_n=400)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    genomes = jax.vmap(lambda k: random_genome(k, spec))(
        jax.random.split(jax.random.PRNGKey(0), lam))

    @jax.jit
    def fused(gs):
        return jax.vmap(
            lambda g: ref.cgp_eval_ref(g, spec, planes, gvals, 256.0))(gs)

    @jax.jit
    def unfused(gs):
        def one(g):
            vals = S.simulate_values(g, spec, planes)       # pass 1
            met = M.metrics_from_values(gvals, vals, spec.n_o)  # pass 2
            wires = S.simulate_planes(g, spec, planes)      # re-sim for p
            p = S.signal_probabilities(wires[spec.n_i:])
            return met, p
        return jax.vmap(one)(gs)

    t_f = _time(fused, genomes)
    t_u = _time(unfused, genomes)
    evals = lam
    return {
        "fused_us_per_eval": 1e6 * t_f / evals,
        "unfused_us_per_eval": 1e6 * t_u / evals,
        "fused_speedup": t_u / t_f,
        "inputs_per_s_fused": evals * spec.n_inputs_total / t_f,
    }


def bench_pallas_interpret(width: int = 6):
    """Interpret-mode cost of the Pallas kernel (correctness path only —
    the performance target is the TPU lowering)."""
    gold, spec = G.array_multiplier(width, n_n=250)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    t_k = _time(lambda: ops.cgp_eval(gold, spec, planes, gvals), reps=3)
    t_r = _time(lambda: ref.cgp_eval_ref(gold, spec, planes, gvals, 256.0),
                reps=3)
    return {"pallas_interpret_ms": 1e3 * t_k, "jnp_ref_ms": 1e3 * t_r}


def bench_generation_rate(width: int = 8):
    """End-to-end (1+λ) generations/s — the paper's search-speed metric."""
    from repro.core.evolve import EvolveConfig, evolve
    from repro.core.fitness import ConstraintSpec
    from repro.core.search import SearchConfig, problem_arrays
    cfg = SearchConfig(width=width, n_n=400,
                       evolve=EvolveConfig(generations=100, lam=8))
    gold, spec, planes, gvals, gpower = problem_arrays(cfg)
    thr = jnp.asarray(ConstraintSpec(mae=1.0).thresholds())

    def run(seed):
        return evolve(spec, cfg.evolve, gold, thr, planes, gvals, gpower,
                      jax.random.PRNGKey(seed)).best_fit

    run(0)  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(run(1))
    dt = time.perf_counter() - t0
    return {"generations_per_s": 100 / dt,
            "evals_per_s": 100 * 8 / dt,
            "exhaustive_inputs_per_s": 100 * 8 * spec.n_inputs_total / dt}


def bench_sweep(width: int = 3, gens: int = 200, lam: int = 4,
                n_seeds: int = 2, backends: tuple = ("jnp", "pallas")):
    """Constraint-grid throughput (runs/s): batched engine vs serial loop,
    with a ``backend`` axis over the candidate-evaluation path.

    The grid is 6 constraint configs × ``n_seeds`` seeds; all paths are
    compiled before timing, so the ratios isolate execution throughput (the
    batched engine additionally saves one trace per seed on the cold path).
    The "pallas" leg drives the fused (runs × λ) kernel — on CPU it runs in
    interpret mode, so its runs/s is a correctness-path reference; the
    jnp-vs-pallas gap worth tracking is on a TPU backend.
    """
    import dataclasses

    from repro.core.evolve import EvolveConfig
    from repro.core.fitness import ConstraintSpec
    from repro.core.search import SearchConfig, run_search, run_sweep_serial
    from repro.core.sweep import SweepConfig, run_sweep_batched

    cfg = SearchConfig(width=width, n_n=100,
                       evolve=EvolveConfig(generations=gens, lam=lam))
    cons = ([ConstraintSpec(mae=t) for t in (0.3, 0.6, 1.0, 2.0)]
            + [ConstraintSpec(er=e) for e in (30.0, 60.0)])
    seeds = tuple(range(n_seeds))
    n_runs = len(cons) * len(seeds)
    sweep = SweepConfig(chunk_size=n_runs, keep_history=False)

    run_search(cfg, cons[0], 0)                       # compile serial path
    t0 = time.perf_counter()
    run_sweep_serial(cfg, cons, seeds)
    t_serial = time.perf_counter() - t0
    out = {"n_runs": n_runs, "serial_runs_per_s": n_runs / t_serial}

    for backend in backends:
        cfg_b = dataclasses.replace(
            cfg, evolve=dataclasses.replace(cfg.evolve, backend=backend))
        run_sweep_batched(cfg_b, cons, seeds, sweep)  # compile batched path
        t0 = time.perf_counter()
        run_sweep_batched(cfg_b, cons, seeds, sweep)
        t_b = time.perf_counter() - t0
        out[f"batched_{backend}_runs_per_s"] = n_runs / t_b
        out[f"batched_{backend}_speedup"] = t_serial / t_b
    return out


def bench_results(n_runs: int = 2048, gens: int = 256, chunk: int = 128,
                  n_n: int = 100, n_o: int = 8):
    """Streaming results layer: shard spill and read-back rows/s.

    Synthetic run-major buffers at realistic shapes (the per-row payload is
    dominated by ``hist_metrics``: gens × N_METRICS floats) are committed
    chunk-by-chunk through ``SweepResultWriter`` and drained back through
    ``SweepResultReader`` — the host-side path that bounds paper-scale grids
    now that the fused kernel owns the evaluation side.  "summary" read-back
    is the figure-pipeline path (correlations + fronts from grid-order
    summary columns); "history" read-back drains every history shard at
    one-chunk peak memory.
    """
    import tempfile

    from repro.core import metrics as M
    from repro.core.results import SweepResultReader, SweepResultWriter

    rng = np.random.default_rng(0)
    rows_all = {
        "grid_rows": np.arange(n_runs, dtype=np.int32),
        "thresholds": rng.random((n_runs, M.N_METRICS), np.float32),
        "parent_nodes": rng.integers(0, 99, (n_runs, n_n, 3), np.int32),
        "parent_outs": rng.integers(0, 99, (n_runs, n_o), np.int32),
        "best_nodes": rng.integers(0, 99, (n_runs, n_n, 3), np.int32),
        "best_outs": rng.integers(0, 99, (n_runs, n_o), np.int32),
        "best_fit": rng.random(n_runs, np.float32),
        "metrics": rng.random((n_runs, M.N_METRICS), np.float32),
        "power_rel": rng.random(n_runs, np.float32),
        "feasible": rng.integers(0, 2, n_runs, np.uint8),
        "error_mean": rng.random(n_runs, np.float32),
        "error_std": rng.random(n_runs, np.float32),
        "hist_power_rel": rng.random((n_runs, gens), np.float32),
        "hist_fit": rng.random((n_runs, gens), np.float32),
        "hist_metrics": rng.random((n_runs, gens, M.N_METRICS), np.float32),
    }
    grid_meta = [{"constraint": f"mae<={i % 7}%", "seed": i,
                  "gauss_sigma": 256.0} for i in range(n_runs)]
    with tempfile.TemporaryDirectory() as d:
        writer = SweepResultWriter(
            d, grid_fingerprint="bench", grid_meta=grid_meta, n_runs=n_runs,
            gens=gens, n_n=n_n, n_o=n_o, keep_history="summary",
            chunk_size=chunk)
        t0 = time.perf_counter()
        for start in range(0, n_runs, chunk):
            end = min(start + chunk, n_runs)
            writer.write_chunk(
                (start, end),
                {k: v[start:end] for k, v in rows_all.items()})
        t_spill = time.perf_counter() - t0

        reader = SweepResultReader(d)
        t0 = time.perf_counter()
        reader.correlations()
        reader.fronts()
        t_summary = time.perf_counter() - t0
        t0 = time.perf_counter()
        drained = 0
        for rows, hist in reader.iter_history():
            drained += hist["hist_metrics"].shape[0]
        t_hist = time.perf_counter() - t0
        assert drained == n_runs

    row_bytes = sum(v.nbytes for v in rows_all.values()) / n_runs
    return {
        "spill_rows_per_s": n_runs / t_spill,
        "spill_mb_per_s": n_runs * row_bytes / t_spill / 2**20,
        "summary_readback_rows_per_s": n_runs / t_summary,
        "history_readback_rows_per_s": n_runs / t_hist,
        "row_kb": row_bytes / 1024,
    }


def main(argv=None):
    import argparse
    import functools
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: eval,gen,pallas,sweep,results")
    ap.add_argument("--backend", default="jnp,pallas",
                    help="comma list of sweep-engine backends to time "
                         "(--only sweep axis; default: jnp,pallas)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    backends = tuple(b for b in args.backend.split(",") if b)
    if unknown := set(backends) - {"jnp", "pallas"}:
        ap.error(f"unknown backend(s): {sorted(unknown)}")
    benches = {"eval": bench_eval_throughput, "gen": bench_generation_rate,
               "pallas": bench_pallas_interpret,
               "sweep": functools.partial(bench_sweep, backends=backends),
               "results": bench_results}
    if only is not None and (unknown := only - set(benches)):
        ap.error(f"unknown bench name(s): {sorted(unknown)} "
                 f"(choose from {sorted(benches)})")
    for name, fn in benches.items():
        if only is not None and name not in only:
            continue
        out = fn()
        parts = ", ".join(f"{k}={v:.4g}" for k, v in out.items())
        print(f"[{name}] {parts}", flush=True)


if __name__ == "__main__":
    main()

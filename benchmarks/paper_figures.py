"""One experiment per paper figure, sliced from ONE shared sweep grid
(reduced budget; see DESIGN.md §2).

The paper gives every CGP run 1 hour on a 14-core Xeon (~10^6 evaluations);
this container is a single CPU core, so each figure uses the same protocol at
a reduced budget (generations × λ below, 6-bit multipliers for the wide
sweeps, 8-bit for the headline comparisons).  What must REPRODUCE is the
*qualitative* claim of each figure (ER antagonism, ACC0 ~free, combined
ER+MAE/WCE winning globally, …); each fig_* function returns rows AND a
`claims` dict of booleans checked against the paper's statements.

Execution model (DESIGN.md §3): every figure except Fig. 14 declares its
constraint list up front; the union is deduplicated into ONE grid, executed
once through ``search.run_sweep`` with the streaming results layer
(``keep_history="summary"``, shards under ``RESULTS_DIR/grids/``), and each
figure slices its rows from the ``SweepResultReader``.  A run's result
depends only on its ``(constraint, seed)`` pair (per-run PRNG streams), so
the slices are bit-identical to what per-figure sweeps would produce — but
shared rows (e.g. the wce≤0.5..2 sweeps of Figs. 6/8/9) are evolved once,
and an interrupted figure pass resumes mid-grid from the shard set.
Fig. 14 runs its own grid (8-bit, 2.5× budget) through the same machinery.

Each figure JSON is stamped with the source grid's fingerprint and the
budget knobs, so a committed artifact that no longer matches the code or
budget that would regenerate it is detectable (DESIGN.md §3.4).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.pareto import hypervolume_2d, metric_correlations, pareto_points
from repro.core.results import SweepResultReader
from repro.core.search import CircuitRecord, SearchConfig, run_sweep

# default artifact dir is repo-anchored (NOT CWD-relative), so figure runs
# land in experiments/paper/ no matter where the benchmark is invoked from
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR") or os.path.join(
    _REPO, "experiments", "paper")

# reduced-budget knobs (the full-paper protocol would use width=8,
# n_n=400, ~1e6 evals; trends are stable from these budgets)
WIDTH = int(os.environ.get("REPRO_BENCH_WIDTH", "6"))
GENS = int(os.environ.get("REPRO_BENCH_GENS", "1200"))
LAM = int(os.environ.get("REPRO_BENCH_LAM", "8"))
SEEDS = tuple(range(int(os.environ.get("REPRO_BENCH_SEEDS", "3"))))
CHUNK = int(os.environ.get("REPRO_BENCH_CHUNK", "32"))
NODES = 400 if WIDTH >= 8 else 250


def _cfg(gens=None, width=None, n_n=None) -> SearchConfig:
    return SearchConfig(width=width or WIDTH,
                        n_n=n_n or (400 if (width or WIDTH) >= 8 else NODES),
                        evolve=EvolveConfig(generations=gens or GENS,
                                            lam=LAM))


# --------------------------------------------------------------------------
# Per-figure constraint declarations (the shared grid is their union)
# --------------------------------------------------------------------------

FIG5_CONS = [ConstraintSpec(avg=t) for t in (0.01, 0.1, 1.0)]
FIG6_WCE = [ConstraintSpec(wce=t) for t in (0.1, 0.5, 1.0, 2.0, 5.0)]
FIG6_MAE = [ConstraintSpec(mae=t) for t in (0.05, 0.1, 0.5, 1.0, 2.0)]
FIG7_SWEEPS = {
    "mae": [ConstraintSpec(mae=t) for t in (0.05, 0.2, 0.5, 1.0, 2.0)],
    "wce": [ConstraintSpec(wce=t) for t in (0.2, 0.5, 1.0, 2.0, 5.0)],
    "er": [ConstraintSpec(er=t) for t in (10, 25, 50, 75, 90)],
    "mre": [ConstraintSpec(mre=t) for t in (1, 5, 10, 25, 50)],
}
FIG8_TS = (0.2, 0.5, 1.0, 2.0)
FIG8_PLAIN = [ConstraintSpec(wce=t) for t in FIG8_TS]
FIG8_ACC0 = [ConstraintSpec(wce=t, acc0=True) for t in FIG8_TS]
FIG9_TS = (0.5, 1.0, 2.0)
FIG9_PLAIN = [ConstraintSpec(wce=t) for t in FIG9_TS]
FIG9_TIGHT = [ConstraintSpec(wce=t, avg=0.01) for t in FIG9_TS]
FIG9_LOOSE = [ConstraintSpec(wce=t, avg=0.2) for t in FIG9_TS]
FIG10_COMBOS = ([ConstraintSpec(er=e, mae=m) for e in (30, 50, 70)
                 for m in (0.2, 1.0)] +
                [ConstraintSpec(er=e, wce=w) for e in (30, 50, 70)
                 for w in (0.5, 2.0)])
FIG11_CONS = [ConstraintSpec(wce=w, mre=m)
              for w in (0.5, 2.0) for m in (2.0, 10.0, 50.0)]
_SIGMA_REL = {6: 1.0, 8: 4.0}.get(WIDTH, 1.0)
FIG12_GAUSS = [ConstraintSpec(wce=w, gauss=True, gauss_sigma=s * _SIGMA_REL)
               for w in (1.0, 2.0) for s in (2.0, 8.0)]
FIG12_MAE_AVG = [ConstraintSpec(mae=m, avg=0.05) for m in (0.2, 0.5, 1.0)]

FIG14_STRATEGIES = {
    "mae": [ConstraintSpec(mae=t) for t in (0.2, 0.5, 1.5)],
    "wce": [ConstraintSpec(wce=t) for t in (0.5, 2.0, 5.0)],
    "er": [ConstraintSpec(er=t) for t in (30, 50, 70)],
    "mre": [ConstraintSpec(mre=t) for t in (5, 10, 25)],
    "er+mae": [ConstraintSpec(er=e, mae=m)
               for e in (50, 70) for m in (0.5, 1.5)],
    "er+wce": [ConstraintSpec(er=e, wce=w)
               for e in (50, 70) for w in (2.0, 5.0)],
}


def shared_constraints() -> list[ConstraintSpec]:
    """Deduplicated union of every shared-grid figure's constraints, in
    first-appearance order (the grid's run order)."""
    groups = ([FIG5_CONS, FIG6_WCE, FIG6_MAE] + list(FIG7_SWEEPS.values())
              + [FIG8_PLAIN, FIG8_ACC0, FIG9_PLAIN, FIG9_TIGHT, FIG9_LOOSE,
                 FIG10_COMBOS, FIG11_CONS, FIG12_GAUSS, FIG12_MAE_AVG])
    out, seen = [], set()
    for cons in groups:
        for c in cons:
            key = (c.describe(), float(c.gauss_sigma))
            if key not in seen:
                seen.add(key)
                out.append(c)
    return out


# --------------------------------------------------------------------------
# Shared-grid execution (run once, slice per figure from the reader)
# --------------------------------------------------------------------------

_READER_CACHE: dict[str, SweepResultReader] = {}


def _grid_reader(tag: str, cfg: SearchConfig,
                 constraints: list[ConstraintSpec],
                 seeds: tuple) -> SweepResultReader:
    """Execute a grid once through the streaming results layer and return
    its reader.  The shard directory is namespaced by the grid fingerprint,
    so a budget/code change gets a fresh directory while an identical rerun
    (or an interrupted pass) resumes from the committed shards."""
    from repro.core.sweep import SweepConfig, grid_fingerprint, sweep_grid
    fp = grid_fingerprint(cfg, sweep_grid(constraints, seeds), "summary")
    # chunk size is pinned in the shard manifest (spans are the chunked
    # execution partition), so it namespaces the directory alongside the
    # grid fingerprint — changing REPRO_BENCH_CHUNK gets a fresh grid dir
    rdir = os.path.join(RESULTS_DIR, "grids", f"{tag}-{fp[:12]}-c{CHUNK}")
    if rdir not in _READER_CACHE:
        run_sweep(cfg, constraints, seeds=seeds,
                  sweep=SweepConfig(chunk_size=CHUNK, keep_history="summary",
                                    results_dir=rdir))
        _READER_CACHE[rdir] = SweepResultReader(rdir)
    return _READER_CACHE[rdir]


def shared_reader() -> SweepResultReader:
    """The ONE grid behind Figs. 5-12 at the (WIDTH, GENS, SEEDS) budget."""
    return _grid_reader("shared", _cfg(), shared_constraints(), SEEDS)


def fig14_reader() -> SweepResultReader:
    """Fig. 14's own grid: the paper's exact operating point (8x8, n_n=400)
    at 2.5x the generation budget, one seed."""
    cons = [c for cs in FIG14_STRATEGIES.values() for c in cs]
    return _grid_reader("fig14", _cfg(gens=int(2.5 * GENS), width=8),
                        cons, SEEDS[:1])


_RECORD_INDEX: dict[str, dict] = {}


def _select(reader: SweepResultReader, constraints: list[ConstraintSpec],
            seeds: tuple = SEEDS) -> list[CircuitRecord]:
    """Slice a figure's records out of a grid reader, in the order a
    dedicated ``run_sweep(constraints, seeds)`` would return them.  The
    (constraint, seed) -> record index is built once per grid directory —
    figures slice it ~20 times per pass, and rebuilding it would re-read
    the whole shard set each time."""
    if reader.results_dir not in _RECORD_INDEX:
        _RECORD_INDEX[reader.results_dir] = {
            (r.constraint, r.seed): r for r in reader.records()}
    index = _RECORD_INDEX[reader.results_dir]
    return [index[(c.describe(), s)] for c in constraints for s in seeds]


def _save(name: str, rows: list[dict], claims: dict,
          reader: SweepResultReader | None = None) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = {"figure": name, "width": WIDTH, "gens": GENS, "lam": LAM,
           "grid_fingerprint": reader.fingerprint if reader else None,
           "budget": {"width": WIDTH, "gens": GENS, "lam": LAM,
                      "seeds": len(SEEDS), "nodes": NODES, "chunk": CHUNK},
           "rows": rows, "claims": claims}
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def _rows(recs: list[CircuitRecord]) -> list[dict]:
    return [{"constraint": r.constraint, "seed": r.seed,
             "power_rel": r.power_rel, "feasible": r.feasible,
             "mae": float(r.metrics[M.MAE]), "wce": float(r.metrics[M.WCE]),
             "er": float(r.metrics[M.ER]), "mre": float(r.metrics[M.MRE]),
             "avg": float(r.metrics[M.AVG]),
             "acc0": float(r.metrics[M.ACC0]),
             "err_std": r.error_std, "err_mean": r.error_mean}
            for r in recs]


# --------------------------------------------------------------------------
# Fig. 5: constraining ONLY the average error degenerates the circuit
# --------------------------------------------------------------------------

def fig5_avg_only():
    grid = shared_reader()
    rows = _rows(_select(grid, FIG5_CONS))
    # degenerate: massive power reduction with terrible WCE/MAE
    deg = [r for r in rows if r["feasible"] and r["power_rel"] < 0.4]
    claims = {
        "avg_only_removes_most_logic": len(deg) > 0,
        "avg_only_wce_useless": all(r["wce"] > 5.0 for r in deg) if deg
        else False,
    }
    return _save("fig5_avg_only", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 6: metric correlations in WCE- vs MAE-constrained circuits
# --------------------------------------------------------------------------

def fig6_correlations():
    grid = shared_reader()
    wce_recs = _select(grid, FIG6_WCE)
    mae_recs = _select(grid, FIG6_MAE)

    def corr_matrix(recs):
        cols = [M.MAE, M.WCE, M.ER, M.MRE, M.AVG]
        X = np.array([[r.metrics[c] for c in cols] for r in recs])
        if len(recs) < 3:
            return None
        return metric_correlations(X)

    cw = corr_matrix(wce_recs)
    cm = corr_matrix(mae_recs)
    names = ["mae", "wce", "er", "mre", "avg"]
    rows = ([{"set": "wce", "matrix": cw.tolist(), "names": names}]
            + [{"set": "mae", "matrix": cm.tolist(), "names": names}]
            + _rows(wce_recs + mae_recs))
    # paper: under MAE constraints, WCE stays within ~3.2x MAE.  The exact
    # constant is budget/width-specific (their 1-hour 8-bit runs polish the
    # error tail; short runs leave sloppier worst cases), so the qualitative
    # check is "same order of magnitude" and the measured max ratio is
    # reported as data — the deviation is discussed in EXPERIMENTS.md.
    mae_feas = [r for r in _rows(mae_recs) if r["feasible"]
                and r["mae"] > 1e-4]
    ratio = max((r["wce"] / r["mae"] for r in mae_feas), default=0.0)
    claims = {
        "wce_set_correlates_mae_wce": bool(cw is not None
                                           and cw[0, 1] > 0.6),
        "er_least_correlated_in_wce_set": bool(
            cw is not None and
            np.argmin([cw[2, j] for j in (0, 1, 3, 4)]) is not None and
            cw[0, 2] <= max(cw[0, 1], cw[0, 3]) + 1e-9),
        "wce_within_order_of_paper_3.2x_bound": bool(0 < ratio <= 32.0),
        "max_wce_over_mae_ratio": float(ratio),
    }
    return _save("fig6_correlations", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 2/7: single-metric objectives do NOT give global quality;
# ER is antagonistic to the other metrics
# --------------------------------------------------------------------------

def fig7_single_metric_tradeoffs():
    grid = shared_reader()
    all_rows = []
    by_obj = {}
    for obj, cons in FIG7_SWEEPS.items():
        rows = _rows(_select(grid, cons))
        for r in rows:
            r["objective"] = obj
        by_obj[obj] = [r for r in rows if r["feasible"]]
        all_rows += rows

    def hv(rows, metric):
        pts = np.array([[r["power_rel"], r[metric]] for r in rows]) \
            if rows else np.zeros((0, 2))
        ref = {"mae": (1.05, 25.0), "er": (1.05, 100.0)}[metric]
        return hypervolume_2d(pts, ref)

    # ER-optimized circuits dominate the power-ER trade-off...
    hv_er_on_er = hv(by_obj["er"], "er")
    hv_mae_on_er = hv(by_obj["mae"], "er")
    # ...but are poor on MAE, and vice versa
    hv_mae_on_mae = hv(by_obj["mae"], "mae")
    hv_er_on_mae = hv(by_obj["er"], "mae")
    claims = {
        "er_objective_best_for_er": hv_er_on_er > hv_mae_on_er,
        "mae_objective_best_for_mae": hv_mae_on_mae > hv_er_on_mae,
        "hv_er_on_er": hv_er_on_er, "hv_mae_on_er": hv_mae_on_er,
        "hv_mae_on_mae": hv_mae_on_mae, "hv_er_on_mae": hv_er_on_mae,
    }
    return _save("fig7_single_metric_tradeoffs", all_rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 8: adding ACC0 is (almost) free
# --------------------------------------------------------------------------

def fig8_acc0():
    grid = shared_reader()
    plain = _select(grid, FIG8_PLAIN)
    with0 = _select(grid, FIG8_ACC0)
    rows = _rows(plain) + _rows(with0)
    p_med = np.median([r.power_rel for r in plain if r.feasible])
    a_med = np.median([r.power_rel for r in with0 if r.feasible])
    claims = {
        "acc0_cost_below_5pct": bool(abs(a_med - p_med) < 0.05),
        "median_power_plain": float(p_med),
        "median_power_acc0": float(a_med),
        "all_acc0_circuits_exact_on_zero": all(
            r.metrics[M.ACC0] == 1 for r in with0 if r.feasible),
    }
    return _save("fig8_acc0", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 9: WCE + AVG costs power when AVG is tight
# --------------------------------------------------------------------------

def fig9_wce_avg():
    grid = shared_reader()
    plain = _select(grid, FIG9_PLAIN)
    tight = _select(grid, FIG9_TIGHT)
    loose = _select(grid, FIG9_LOOSE)
    rows = _rows(plain) + _rows(tight) + _rows(loose)
    med = lambda rs: float(np.median([r.power_rel for r in rs
                                      if r.feasible]) if any(
        r.feasible for r in rs) else 1.0)
    claims = {
        "tight_avg_costs_power": med(tight) >= med(plain) - 0.01,
        "power_plain": med(plain), "power_avg_tight": med(tight),
        "power_avg_loose": med(loose),
    }
    return _save("fig9_wce_avg", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 10: combining ER with MAE/WCE; ER constraint caps achievable MAE
# --------------------------------------------------------------------------

def fig10_er_combos():
    grid = shared_reader()
    rows = _rows(_select(grid, FIG10_COMBOS))
    # paper: with ER<=30 the MAE stays low even when unconstrained-ish
    er30 = [r for r in rows if r["feasible"] and "er<=30" in r["constraint"]]
    claims = {
        "er_constraint_caps_mae": all(r["mae"] < 5.0 for r in er30)
        if er30 else False,
        "feasible_fraction": float(np.mean([r["feasible"] for r in rows])),
    }
    return _save("fig10_er_combos", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 11: WCE + MRE trade-offs
# --------------------------------------------------------------------------

def fig11_wce_mre():
    grid = shared_reader()
    rows = _rows(_select(grid, FIG11_CONS))
    claims = {"all_respect_both": all(
        (r["wce"] <= 2.0 + 1e-3 and r["mre"] <= 50 + 1e-3)
        for r in rows if r["feasible"])}
    return _save("fig11_wce_mre", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 12/13: the Gauss_σ constraint is hard for CGP; MAE+AVG runs give
# near-gaussian error distributions more cheaply
# --------------------------------------------------------------------------

def fig12_gauss():
    grid = shared_reader()
    gauss = _select(grid, FIG12_GAUSS)
    mae_avg = _select(grid, FIG12_MAE_AVG)
    rows = _rows(gauss) + [dict(r, set="mae_avg") for r in _rows(mae_avg)]
    med = lambda rs: float(np.median([r.power_rel for r in rs if r.feasible])
                           if any(r.feasible for r in rs) else 1.0)
    claims = {
        "gauss_lower_reduction_than_mae_avg": med(gauss) >= med(mae_avg),
        "power_gauss": med(gauss), "power_mae_avg": med(mae_avg),
        "mae_avg_near_zero_mean": all(
            abs(r.error_mean) < 50 for r in mae_avg if r.feasible),
    }
    return _save("fig12_gauss", rows, claims, grid)


# --------------------------------------------------------------------------
# Fig. 1/14: global comparison — ER+MAE / ER+WCE give global quality
# --------------------------------------------------------------------------

def fig14_global_pareto():
    """Global comparison (paper Fig. 14).  The paper's precise statements:
    (i) combined ER+MAE / ER+WCE give "almost optimal trade-offs for the ER
    and MRE"; (ii) "for the MAE and WCE, the circuits slightly lag behind
    the best" but remain good; (iii) ER-only is far from optimal on
    MAE/WCE; (iv) "surprisingly, the single MRE constraint provides very
    good trade-offs across the remaining metrics" when ER is not needed.
    This headline figure runs at the paper's exact operating point
    (8x8 multiplier, n_n=400, exhaustive 2^16) with 2.5x the generation
    budget (equal across strategies; the ER/MAE antagonism the paper
    reports is much weaker at reduced widths)."""
    grid = fig14_reader()
    rows = []
    hv = {}
    for name, cons in FIG14_STRATEGIES.items():
        rs = _rows(_select(grid, cons, seeds=SEEDS[:1]))
        for r in rs:
            r["strategy"] = name
        rows += rs
        feas = [r for r in rs if r["feasible"]]
        for metric, ref in (("mae", (1.05, 25.0)), ("wce", (1.05, 60.0)),
                            ("er", (1.05, 100.0)), ("mre", (1.05, 100.0))):
            pts = np.array([[r["power_rel"], r[metric]] for r in feas]) \
                if feas else np.zeros((0, 2))
            hv[f"{name}|{metric}"] = hypervolume_2d(pts, ref)

    def norm(name, metric):
        best = max(hv[f"{s}|{metric}"] for s in FIG14_STRATEGIES) or 1.0
        return hv[f"{name}|{metric}"] / best

    scores = {n: float(np.mean([norm(n, m) for m in
                                ("mae", "wce", "er", "mre")]))
              for n in FIG14_STRATEGIES}

    # The paper's global-quality argument, programmatically: at each ER
    # level, the ER+MAE/ER+WCE circuit matches the ER-only circuit's power
    # (within a few %) while improving MAE/WCE/MRE by large factors ("adding
    # the MAE/WCE constraint to the ER further improves the trade-offs").
    feas = [r for r in rows if r["feasible"]]
    dominate_checks = []
    for er_t in (50, 70):
        er_only = [r for r in feas if r["strategy"] == "er"
                   and r["er"] <= er_t + 0.5]
        combos = [r for r in feas if r["strategy"] in ("er+mae", "er+wce")
                  and r["er"] <= er_t + 0.5]
        if not er_only or not combos:
            continue
        base = min(er_only, key=lambda r: r["power_rel"])
        best = min(combos, key=lambda r: r["mae"])
        dominate_checks.append({
            "er_level": er_t,
            "power_delta": best["power_rel"] - base["power_rel"],
            "mae_improvement": base["mae"] / max(best["mae"], 1e-9),
            "wce_improvement": base["wce"] / max(best["wce"], 1e-9),
            "mre_improvement": base["mre"] / max(best["mre"], 1e-9),
            "ok": (best["power_rel"] <= base["power_rel"] + 0.03
                   and base["mae"] >= 2 * best["mae"]
                   and base["wce"] >= 2 * best["wce"]
                   and base["mre"] >= 1.5 * best["mre"]),
        })
    # antagonism: MAE/WCE-optimized circuits are useless on ER (paper Fig. 2)
    mae_ers = [r["er"] for r in feas if r["strategy"] in ("mae", "wce")]
    claims = {
        "combined_matches_er_only_power_and_dominates_other_metrics":
            bool(dominate_checks) and all(c["ok"] for c in dominate_checks),
        "dominate_checks": dominate_checks,
        "mae_wce_objectives_useless_on_er": bool(
            mae_ers and min(mae_ers) > 90.0),
        "er_only_poor_on_mae": norm("er", "mae") < 0.7,
        "mre_single_good_on_magnitude_metrics": (
            norm("mre", "mae") >= 0.3 and norm("mre", "wce") >= 0.15),
        "scores_mean": scores, "hypervolumes": hv,
    }
    return _save("fig14_global_pareto", rows, claims, grid)


ALL_FIGURES = [fig5_avg_only, fig6_correlations, fig7_single_metric_tradeoffs,
               fig8_acc0, fig9_wce_avg, fig10_er_combos, fig11_wce_mre,
               fig12_gauss, fig14_global_pareto]

"""One experiment per paper figure (reduced budget; see DESIGN.md §2).

The paper gives every CGP run 1 hour on a 14-core Xeon (~10^6 evaluations);
this container is a single CPU core, so each figure uses the same protocol at
a reduced budget (generations × λ below, 6-bit multipliers for the wide
sweeps, 8-bit for the headline comparisons).  What must REPRODUCE is the
*qualitative* claim of each figure (ER antagonism, ACC0 ~free, combined
ER+MAE/WCE winning globally, …); each fig_* function returns rows AND a
`claims` dict of booleans checked against the paper's statements.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.pareto import hypervolume_2d, metric_correlations, pareto_points
from repro.core.search import CircuitRecord, SearchConfig, run_sweep

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "experiments/paper")

# reduced-budget knobs (the full-paper protocol would use width=8,
# n_n=400, ~1e6 evals; trends are stable from these budgets)
WIDTH = int(os.environ.get("REPRO_BENCH_WIDTH", "6"))
GENS = int(os.environ.get("REPRO_BENCH_GENS", "1200"))
LAM = int(os.environ.get("REPRO_BENCH_LAM", "8"))
SEEDS = tuple(range(int(os.environ.get("REPRO_BENCH_SEEDS", "3"))))
NODES = 400 if WIDTH >= 8 else 250


def _cfg(gens=None, width=None, n_n=None) -> SearchConfig:
    return SearchConfig(width=width or WIDTH,
                        n_n=n_n or (400 if (width or WIDTH) >= 8 else NODES),
                        evolve=EvolveConfig(generations=gens or GENS,
                                            lam=LAM))


def _sweep(constraints, gens=None, seeds=SEEDS, width=None
           ) -> list[CircuitRecord]:
    return run_sweep(_cfg(gens, width), constraints, seeds=seeds)


def _save(name: str, rows: list[dict], claims: dict) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = {"figure": name, "width": WIDTH, "gens": GENS, "lam": LAM,
           "rows": rows, "claims": claims}
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


def _rows(recs: list[CircuitRecord]) -> list[dict]:
    return [{"constraint": r.constraint, "seed": r.seed,
             "power_rel": r.power_rel, "feasible": r.feasible,
             "mae": float(r.metrics[M.MAE]), "wce": float(r.metrics[M.WCE]),
             "er": float(r.metrics[M.ER]), "mre": float(r.metrics[M.MRE]),
             "avg": float(r.metrics[M.AVG]),
             "acc0": float(r.metrics[M.ACC0]),
             "err_std": r.error_std, "err_mean": r.error_mean}
            for r in recs]


# --------------------------------------------------------------------------
# Fig. 5: constraining ONLY the average error degenerates the circuit
# --------------------------------------------------------------------------

def fig5_avg_only():
    recs = _sweep([ConstraintSpec(avg=t) for t in (0.01, 0.1, 1.0)],
                  gens=GENS)
    rows = _rows(recs)
    # degenerate: massive power reduction with terrible WCE/MAE
    deg = [r for r in rows if r["feasible"] and r["power_rel"] < 0.4]
    claims = {
        "avg_only_removes_most_logic": len(deg) > 0,
        "avg_only_wce_useless": all(r["wce"] > 5.0 for r in deg) if deg
        else False,
    }
    return _save("fig5_avg_only", rows, claims)


# --------------------------------------------------------------------------
# Fig. 6: metric correlations in WCE- vs MAE-constrained circuits
# --------------------------------------------------------------------------

def fig6_correlations():
    wce_recs = _sweep([ConstraintSpec(wce=t)
                       for t in (0.1, 0.5, 1.0, 2.0, 5.0)])
    mae_recs = _sweep([ConstraintSpec(mae=t)
                       for t in (0.05, 0.1, 0.5, 1.0, 2.0)])

    def corr_matrix(recs):
        cols = [M.MAE, M.WCE, M.ER, M.MRE, M.AVG]
        X = np.array([[r.metrics[c] for c in cols] for r in recs])
        if len(recs) < 3:
            return None
        return metric_correlations(X)

    cw = corr_matrix(wce_recs)
    cm = corr_matrix(mae_recs)
    names = ["mae", "wce", "er", "mre", "avg"]
    rows = ([{"set": "wce", "matrix": cw.tolist(), "names": names}]
            + [{"set": "mae", "matrix": cm.tolist(), "names": names}]
            + _rows(wce_recs + mae_recs))
    # paper: under MAE constraints, WCE stays within ~3.2x MAE.  The exact
    # constant is budget/width-specific (their 1-hour 8-bit runs polish the
    # error tail; short runs leave sloppier worst cases), so the qualitative
    # check is "same order of magnitude" and the measured max ratio is
    # reported as data — the deviation is discussed in EXPERIMENTS.md.
    mae_feas = [r for r in _rows(mae_recs) if r["feasible"]
                and r["mae"] > 1e-4]
    ratio = max((r["wce"] / r["mae"] for r in mae_feas), default=0.0)
    claims = {
        "wce_set_correlates_mae_wce": bool(cw is not None
                                           and cw[0, 1] > 0.6),
        "er_least_correlated_in_wce_set": bool(
            cw is not None and
            np.argmin([cw[2, j] for j in (0, 1, 3, 4)]) is not None and
            cw[0, 2] <= max(cw[0, 1], cw[0, 3]) + 1e-9),
        "wce_within_order_of_paper_3.2x_bound": bool(0 < ratio <= 32.0),
        "max_wce_over_mae_ratio": float(ratio),
    }
    return _save("fig6_correlations", rows, claims)


# --------------------------------------------------------------------------
# Fig. 2/7: single-metric objectives do NOT give global quality;
# ER is antagonistic to the other metrics
# --------------------------------------------------------------------------

def fig7_single_metric_tradeoffs():
    sweeps = {
        "mae": [ConstraintSpec(mae=t) for t in (0.05, 0.2, 0.5, 1.0, 2.0)],
        "wce": [ConstraintSpec(wce=t) for t in (0.2, 0.5, 1.0, 2.0, 5.0)],
        "er": [ConstraintSpec(er=t) for t in (10, 25, 50, 75, 90)],
        "mre": [ConstraintSpec(mre=t) for t in (1, 5, 10, 25, 50)],
    }
    all_rows = []
    by_obj = {}
    for obj, cons in sweeps.items():
        recs = _sweep(cons)
        rows = _rows(recs)
        for r in rows:
            r["objective"] = obj
        by_obj[obj] = [r for r in rows if r["feasible"]]
        all_rows += rows

    def hv(rows, metric):
        pts = np.array([[r["power_rel"], r[metric]] for r in rows]) \
            if rows else np.zeros((0, 2))
        ref = {"mae": (1.05, 25.0), "er": (1.05, 100.0)}[metric]
        return hypervolume_2d(pts, ref)

    # ER-optimized circuits dominate the power-ER trade-off...
    hv_er_on_er = hv(by_obj["er"], "er")
    hv_mae_on_er = hv(by_obj["mae"], "er")
    # ...but are poor on MAE, and vice versa
    hv_mae_on_mae = hv(by_obj["mae"], "mae")
    hv_er_on_mae = hv(by_obj["er"], "mae")
    claims = {
        "er_objective_best_for_er": hv_er_on_er > hv_mae_on_er,
        "mae_objective_best_for_mae": hv_mae_on_mae > hv_er_on_mae,
        "hv_er_on_er": hv_er_on_er, "hv_mae_on_er": hv_mae_on_er,
        "hv_mae_on_mae": hv_mae_on_mae, "hv_er_on_mae": hv_er_on_mae,
    }
    return _save("fig7_single_metric_tradeoffs", all_rows, claims)


# --------------------------------------------------------------------------
# Fig. 8: adding ACC0 is (almost) free
# --------------------------------------------------------------------------

def fig8_acc0():
    ts = (0.2, 0.5, 1.0, 2.0)
    plain = _sweep([ConstraintSpec(wce=t) for t in ts])
    with0 = _sweep([ConstraintSpec(wce=t, acc0=True) for t in ts])
    rows = _rows(plain) + _rows(with0)
    p_med = np.median([r.power_rel for r in plain if r.feasible])
    a_med = np.median([r.power_rel for r in with0 if r.feasible])
    claims = {
        "acc0_cost_below_5pct": bool(abs(a_med - p_med) < 0.05),
        "median_power_plain": float(p_med),
        "median_power_acc0": float(a_med),
        "all_acc0_circuits_exact_on_zero": all(
            r.metrics[M.ACC0] == 1 for r in with0 if r.feasible),
    }
    return _save("fig8_acc0", rows, claims)


# --------------------------------------------------------------------------
# Fig. 9: WCE + AVG costs power when AVG is tight
# --------------------------------------------------------------------------

def fig9_wce_avg():
    ts = (0.5, 1.0, 2.0)
    plain = _sweep([ConstraintSpec(wce=t) for t in ts])
    tight = _sweep([ConstraintSpec(wce=t, avg=0.01) for t in ts])
    loose = _sweep([ConstraintSpec(wce=t, avg=0.2) for t in ts])
    rows = _rows(plain) + _rows(tight) + _rows(loose)
    med = lambda rs: float(np.median([r.power_rel for r in rs
                                      if r.feasible]) if any(
        r.feasible for r in rs) else 1.0)
    claims = {
        "tight_avg_costs_power": med(tight) >= med(plain) - 0.01,
        "power_plain": med(plain), "power_avg_tight": med(tight),
        "power_avg_loose": med(loose),
    }
    return _save("fig9_wce_avg", rows, claims)


# --------------------------------------------------------------------------
# Fig. 10: combining ER with MAE/WCE; ER constraint caps achievable MAE
# --------------------------------------------------------------------------

def fig10_er_combos():
    combos = ([ConstraintSpec(er=e, mae=m) for e in (30, 50, 70)
               for m in (0.2, 1.0)] +
              [ConstraintSpec(er=e, wce=w) for e in (30, 50, 70)
               for w in (0.5, 2.0)])
    recs = _sweep(combos)
    rows = _rows(recs)
    # paper: with ER<=30 the MAE stays low even when unconstrained-ish
    er30 = [r for r in rows if r["feasible"] and "er<=30" in r["constraint"]]
    claims = {
        "er_constraint_caps_mae": all(r["mae"] < 5.0 for r in er30)
        if er30 else False,
        "feasible_fraction": float(np.mean([r["feasible"] for r in rows])),
    }
    return _save("fig10_er_combos", rows, claims)


# --------------------------------------------------------------------------
# Fig. 11: WCE + MRE trade-offs
# --------------------------------------------------------------------------

def fig11_wce_mre():
    recs = _sweep([ConstraintSpec(wce=w, mre=m)
                   for w in (0.5, 2.0) for m in (2.0, 10.0, 50.0)])
    rows = _rows(recs)
    claims = {"all_respect_both": all(
        (r["wce"] <= 2.0 + 1e-3 and r["mre"] <= 50 + 1e-3)
        for r in rows if r["feasible"])}
    return _save("fig11_wce_mre", rows, claims)


# --------------------------------------------------------------------------
# Fig. 12/13: the Gauss_σ constraint is hard for CGP; MAE+AVG runs give
# near-gaussian error distributions more cheaply
# --------------------------------------------------------------------------

def fig12_gauss():
    sigma_rel = {6: 1.0, 8: 4.0}.get(WIDTH, 1.0)
    gauss = _sweep([ConstraintSpec(wce=w, gauss=True,
                                   gauss_sigma=s * sigma_rel)
                    for w in (1.0, 2.0) for s in (2.0, 8.0)])
    mae_avg = _sweep([ConstraintSpec(mae=m, avg=0.05)
                      for m in (0.2, 0.5, 1.0)])
    rows = _rows(gauss) + [dict(r, set="mae_avg") for r in _rows(mae_avg)]
    med = lambda rs: float(np.median([r.power_rel for r in rs if r.feasible])
                           if any(r.feasible for r in rs) else 1.0)
    claims = {
        "gauss_lower_reduction_than_mae_avg": med(gauss) >= med(mae_avg),
        "power_gauss": med(gauss), "power_mae_avg": med(mae_avg),
        "mae_avg_near_zero_mean": all(
            abs(r.error_mean) < 50 for r in mae_avg if r.feasible),
    }
    return _save("fig12_gauss", rows, claims)


# --------------------------------------------------------------------------
# Fig. 1/14: global comparison — ER+MAE / ER+WCE give global quality
# --------------------------------------------------------------------------

def fig14_global_pareto():
    """Global comparison (paper Fig. 14).  The paper's precise statements:
    (i) combined ER+MAE / ER+WCE give "almost optimal trade-offs for the ER
    and MRE"; (ii) "for the MAE and WCE, the circuits slightly lag behind
    the best" but remain good; (iii) ER-only is far from optimal on
    MAE/WCE; (iv) "surprisingly, the single MRE constraint provides very
    good trade-offs across the remaining metrics" when ER is not needed.
    This headline figure runs at the paper's exact operating point
    (8x8 multiplier, n_n=400, exhaustive 2^16) with 2.5x the generation
    budget (equal across strategies; the ER/MAE antagonism the paper
    reports is much weaker at reduced widths)."""
    strategies = {
        "mae": [ConstraintSpec(mae=t) for t in (0.2, 0.5, 1.5)],
        "wce": [ConstraintSpec(wce=t) for t in (0.5, 2.0, 5.0)],
        "er": [ConstraintSpec(er=t) for t in (30, 50, 70)],
        "mre": [ConstraintSpec(mre=t) for t in (5, 10, 25)],
        "er+mae": [ConstraintSpec(er=e, mae=m)
                   for e in (50, 70) for m in (0.5, 1.5)],
        "er+wce": [ConstraintSpec(er=e, wce=w)
                   for e in (50, 70) for w in (2.0, 5.0)],
    }
    rows = []
    hv = {}
    for name, cons in strategies.items():
        recs = _sweep(cons, gens=int(2.5 * GENS), seeds=SEEDS[:1],
                      width=8)
        rs = _rows(recs)
        for r in rs:
            r["strategy"] = name
        rows += rs
        feas = [r for r in rs if r["feasible"]]
        for metric, ref in (("mae", (1.05, 25.0)), ("wce", (1.05, 60.0)),
                            ("er", (1.05, 100.0)), ("mre", (1.05, 100.0))):
            pts = np.array([[r["power_rel"], r[metric]] for r in feas]) \
                if feas else np.zeros((0, 2))
            hv[f"{name}|{metric}"] = hypervolume_2d(pts, ref)

    def norm(name, metric):
        best = max(hv[f"{s}|{metric}"] for s in strategies) or 1.0
        return hv[f"{name}|{metric}"] / best

    scores = {n: float(np.mean([norm(n, m) for m in
                                ("mae", "wce", "er", "mre")]))
              for n in strategies}

    # The paper's global-quality argument, programmatically: at each ER
    # level, the ER+MAE/ER+WCE circuit matches the ER-only circuit's power
    # (within a few %) while improving MAE/WCE/MRE by large factors ("adding
    # the MAE/WCE constraint to the ER further improves the trade-offs").
    feas = [r for r in rows if r["feasible"]]
    dominate_checks = []
    for er_t in (50, 70):
        er_only = [r for r in feas if r["strategy"] == "er"
                   and r["er"] <= er_t + 0.5]
        combos = [r for r in feas if r["strategy"] in ("er+mae", "er+wce")
                  and r["er"] <= er_t + 0.5]
        if not er_only or not combos:
            continue
        base = min(er_only, key=lambda r: r["power_rel"])
        best = min(combos, key=lambda r: r["mae"])
        dominate_checks.append({
            "er_level": er_t,
            "power_delta": best["power_rel"] - base["power_rel"],
            "mae_improvement": base["mae"] / max(best["mae"], 1e-9),
            "wce_improvement": base["wce"] / max(best["wce"], 1e-9),
            "mre_improvement": base["mre"] / max(best["mre"], 1e-9),
            "ok": (best["power_rel"] <= base["power_rel"] + 0.03
                   and base["mae"] >= 2 * best["mae"]
                   and base["wce"] >= 2 * best["wce"]
                   and base["mre"] >= 1.5 * best["mre"]),
        })
    # antagonism: MAE/WCE-optimized circuits are useless on ER (paper Fig. 2)
    mae_ers = [r["er"] for r in feas if r["strategy"] in ("mae", "wce")]
    claims = {
        "combined_matches_er_only_power_and_dominates_other_metrics":
            bool(dominate_checks) and all(c["ok"] for c in dominate_checks),
        "dominate_checks": dominate_checks,
        "mae_wce_objectives_useless_on_er": bool(
            mae_ers and min(mae_ers) > 90.0),
        "er_only_poor_on_mae": norm("er", "mae") < 0.7,
        "mre_single_good_on_magnitude_metrics": (
            norm("mre", "mae") >= 0.3 and norm("mre", "wce") >= 0.15),
        "scores_mean": scores, "hypervolumes": hv,
    }
    return _save("fig14_global_pareto", rows, claims)


ALL_FIGURES = [fig5_avg_only, fig6_correlations, fig7_single_metric_tradeoffs,
               fig8_acc0, fig9_wce_avg, fig10_er_combos, fig11_wce_mre,
               fig12_gauss, fig14_global_pareto]

"""Benchmark runner: one function per paper figure + kernel micro.

Prints ``name,us_per_call,derived`` CSV rows (per the harness contract) and
writes full JSON to experiments/paper/.  Figure benchmarks are reduced-budget
paper reproductions (see benchmarks/paper_figures.py docstring); claim
booleans are summarized at the end and consumed by EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --only fig8,micro
  REPRO_BENCH_GENS=300 ... python -m benchmarks.run       # quicker pass
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# CWD-independent: resolve src/ (and the benchmarks package root, for plain
# `python /path/to/benchmarks/run.py` invocation) relative to this file
_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
sys.path.insert(0, os.path.join(_HERE, ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,fig7,fig8,fig9,fig10,"
                         "fig11,fig12,fig14,micro")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import kernel_micro, paper_figures

    rows = []

    def emit(name, us, derived):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived", flush=True)

    # kernel microbenchmarks -------------------------------------------------
    if only is None or "micro" in only:
        t0 = time.perf_counter()
        r = kernel_micro.bench_eval_throughput()
        emit("kernel_fused_eval", r["fused_us_per_eval"],
             f"speedup_vs_unfused={r['fused_speedup']:.2f}")
        emit("kernel_eval_inputs_per_s", 0.0,
             f"{r['inputs_per_s_fused']:.3e}")
        r = kernel_micro.bench_generation_rate()
        emit("evolve_generation", 1e6 / max(r["generations_per_s"], 1e-9),
             f"exhaustive_inputs_per_s={r['exhaustive_inputs_per_s']:.3e}")
        r = kernel_micro.bench_pallas_interpret()
        emit("cgp_pallas_interpret_ms", 1e3 * r["pallas_interpret_ms"],
             f"jnp_ref_ms={r['jnp_ref_ms']:.1f}")
        r = kernel_micro.bench_sweep()
        emit("sweep_batched_run", 1e6 / max(r["batched_jnp_runs_per_s"], 1e-9),
             f"runs_per_s={r['batched_jnp_runs_per_s']:.2f},"
             f"speedup_vs_serial={r['batched_jnp_speedup']:.2f}")
        r = kernel_micro.bench_results()
        emit("results_shard_spill", 1e6 / max(r["spill_rows_per_s"], 1e-9),
             f"spill_mb_per_s={r['spill_mb_per_s']:.1f},"
             f"summary_readback_rows_per_s="
             f"{r['summary_readback_rows_per_s']:.0f}")

    # paper figures ----------------------------------------------------------
    fig_map = {f.__name__.split("_")[0]: f
               for f in paper_figures.ALL_FIGURES}
    claims_all = {}
    for short, fn in fig_map.items():
        if only is not None and short not in only:
            continue
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        ok = all(v for v in out["claims"].values()
                 if isinstance(v, bool))
        claims_all[out["figure"]] = out["claims"]
        emit(out["figure"], 1e6 * dt, f"claims_ok={ok}")

    if claims_all:
        import os
        # stamp the summary with the shard-grid fingerprints the figures
        # were sliced from (paper_figures runs every figure through the
        # streaming SweepResultReader), so stale artifacts are detectable
        claims_all["_meta"] = {
            "grid_fingerprints": sorted(
                {d.rsplit(os.sep, 1)[-1]
                 for d in paper_figures._READER_CACHE}),
            "budget": {"width": paper_figures.WIDTH,
                       "gens": paper_figures.GENS,
                       "lam": paper_figures.LAM,
                       "seeds": len(paper_figures.SEEDS)},
        }
        os.makedirs(paper_figures.RESULTS_DIR, exist_ok=True)
        summary_path = os.path.join(paper_figures.RESULTS_DIR,
                                    "claims_summary.json")
        with open(summary_path, "w") as f:
            json.dump(claims_all, f, indent=1, default=str)
        figs = {k: v for k, v in claims_all.items() if k != "_meta"}
        n_ok = sum(all(v for v in c.values() if isinstance(v, bool))
                   for c in figs.values())
        print(f"# paper-claim check: {n_ok}/{len(figs)} figures "
              f"reproduce their qualitative claims", flush=True)


if __name__ == "__main__":
    main()

"""Async commit pipeline + island migration determinism (DESIGN.md §11).

The background committer is execution-only: a sweep with ``async_commit=True``
must produce BYTE-identical shards, manifest and summaries to the synchronous
path, across the dedup and sampled/certify engine variants.  Migration is
result-changing but deterministic: ``migrate_every=0`` stays byte-identical
to the migration-less engine, and a migrating multi-pod sweep produces the
same bytes regardless of pod launch order (the import schedule is pinned by
the chunk plan, the merge rule is content-based).  The crash-consistency
half of the §11 harness lives in ``test_faults.py``.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import migrate as migrate_mod
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.results import SweepResultReader
from repro.core.search import SearchConfig
from repro.core.sweep import (SweepConfig, grid_fingerprint,
                              run_sweep_batched, sweep_grid)

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
CONSTRAINTS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
               ConstraintSpec(er=50.0)]
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)  # chunk_size 2 -> 3 chunks


def _backend():
    env = os.environ.get("REPRO_TEST_BACKEND")
    return env if env in ("jnp", "pallas") else "jnp"


def _cfg(**evolve_kw):
    ev = dataclasses.replace(CFG.evolve, backend=_backend(), **evolve_kw)
    return dataclasses.replace(CFG, evolve=ev)


def _sweep(results_dir, cfg=None, **kw):
    sweep = SweepConfig(chunk_size=2, keep_history="summary",
                        results_dir=str(results_dir), **kw)
    return run_sweep_batched(cfg or _cfg(), CONSTRAINTS, SEEDS, sweep)


def _dir_bytes(d, prefix=("shard_", "migrants_", "manifest")):
    return {f: open(os.path.join(d, f), "rb").read()
            for f in os.listdir(d) if f.startswith(prefix)}


def _assert_dirs_identical(a, b):
    da, db = _dir_bytes(str(a)), _dir_bytes(str(b))
    assert sorted(da) == sorted(db)
    for name in da:
        assert da[name] == db[name], f"bytes differ: {name}"


# --------------------------------------------------------------------------
# Async commit: execution-only
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dedup", [False, True])
def test_async_commit_bit_identical(tmp_path, dedup):
    """async_commit=True commits the same files, byte for byte, as the
    synchronous path — shards, manifest and reader summaries — with the
    dedup engine variant on either side."""
    sync_d, async_d = tmp_path / "sync", tmp_path / "async"
    r_sync = _sweep(sync_d, dedup=dedup or None)
    r_async = _sweep(async_d, dedup=dedup or None, async_commit=True)
    assert r_sync.completed == r_async.completed == N_RUNS
    _assert_dirs_identical(sync_d, async_d)
    sa = SweepResultReader(str(sync_d)).summary()
    sb = SweepResultReader(str(async_d)).summary()
    for key in sa:
        np.testing.assert_array_equal(sa[key], sb[key])


def test_async_commit_sampled_certify_bit_identical(tmp_path):
    """The §9 sampled + §10 certify engine path is equally committer-
    agnostic (the escalation rewrites happen before the commit is handed
    over)."""
    cfg = _cfg(eval_mode="sampled", sample_size=256, certify=True,
               certify_budget=2)
    r_sync = _sweep(tmp_path / "sync", cfg=cfg)
    r_async = _sweep(tmp_path / "async", cfg=cfg, async_commit=True)
    assert r_sync.certify_stats == r_async.certify_stats
    _assert_dirs_identical(tmp_path / "sync", tmp_path / "async")


def test_async_commit_checkpoint_resume(tmp_path):
    """Checkpoints committed by the background worker are valid resume
    points: an interrupted async sweep continues from them and finishes
    with the same results as an uninterrupted synchronous one."""
    ck, res = str(tmp_path / "ck"), tmp_path / "plain"
    want = _sweep(res)
    sweep = SweepConfig(chunk_size=2, keep_history="summary",
                        checkpoint_dir=ck, async_commit=True)
    part = run_sweep_batched(_cfg(), CONSTRAINTS, SEEDS,
                             dataclasses.replace(sweep, max_chunks=2))
    assert 0 < part.completed < N_RUNS
    full = run_sweep_batched(_cfg(), CONSTRAINTS, SEEDS, sweep)
    assert full.completed == N_RUNS
    np.testing.assert_array_equal(full.metrics, want.metrics)
    np.testing.assert_array_equal(full.power_rel, want.power_rel)


def test_commit_depth_validated():
    with pytest.raises(ValueError, match="commit_depth"):
        SweepConfig(commit_depth=0)


# --------------------------------------------------------------------------
# Migration: off == byte-identical, on == deterministic
# --------------------------------------------------------------------------

def test_migration_off_fingerprint_and_bytes_unchanged(tmp_path):
    """migrate_every=0 (the default) leaves the grid fingerprint AND the
    committed bytes exactly those of the migration-less engine — the
    acceptance bit-identity of ISSUE 9."""
    grid = sweep_grid(CONSTRAINTS, SEEDS)
    base = grid_fingerprint(_cfg(), grid, "summary")
    assert grid_fingerprint(_cfg(), grid, "summary", migrate=None) == base
    on = grid_fingerprint(_cfg(), grid, "summary",
                          migrate={"every": 1, "n_pods": 1, "chunk_size": 2,
                                   "top_k": migrate_mod.MIGRATE_TOP_K})
    assert on != base
    _sweep(tmp_path / "a")
    _sweep(tmp_path / "b", migrate_every=0)
    _assert_dirs_identical(tmp_path / "a", tmp_path / "b")
    with open(tmp_path / "a" / "manifest.json") as f:
        assert json.load(f)["grid_fingerprint"] == base


def test_migration_config_validated(tmp_path):
    with pytest.raises(ValueError, match="results_dir"):
        SweepConfig(migrate_every=1)
    with pytest.raises(ValueError, match="model_axis"):
        SweepConfig(migrate_every=1, results_dir=str(tmp_path),
                    model_axis="model")
    with pytest.raises(ValueError, match="migrate_every"):
        SweepConfig(migrate_every=-1)


def test_migration_single_pod_deterministic(tmp_path):
    """A migrating sweep re-run into a fresh directory reproduces every
    byte — shards AND migrant files — and reports its counters."""
    r1 = _sweep(tmp_path / "a", migrate_every=1)
    r2 = _sweep(tmp_path / "b", migrate_every=1)
    assert r1.completed == r2.completed == N_RUNS
    _assert_dirs_identical(tmp_path / "a", tmp_path / "b")
    assert r1.migrate_stats == r2.migrate_stats
    assert r1.migrate_stats["published"] == 3  # 3 chunks, period 1
    # epochs >= 1 import the previous epoch's published elites
    assert r1.migrate_stats["imported"] > 0


def test_migration_compose_dedup_bit_identical(tmp_path):
    """§8 dedup is still execution-only under migration: the folded seeded
    path produces identical bytes with the phenotype cache on or off."""
    _sweep(tmp_path / "plain", migrate_every=1)
    _sweep(tmp_path / "dedup", migrate_every=1, dedup=True)
    _assert_dirs_identical(tmp_path / "plain", tmp_path / "dedup")


def _two_pod_migrating(d, order):
    """Drive a 2-pod migrating sweep epoch-interleaved inside one process:
    each launch runs at most one epoch (max_chunks == period), so the pods
    alternate like concurrently-progressing processes would."""
    kw = dict(migrate_every=1, migrate_timeout=30.0, n_pods=2)
    done = {}
    for _ in range(4):  # 3 chunks split [2, 1] -> at most 4 single-epoch legs
        for pod in order:
            res = _sweep(d, pod_index=pod, max_chunks=1, **kw)
            done[pod] = res
            if all(r.completed == N_RUNS for r in done.values()) \
                    and len(done) == 2:
                return done
    return done


def test_migration_two_pods_pod_order_independent(tmp_path):
    """Two pods sharing a results_dir converge to the same bytes no matter
    which pod runs first — the import set is plan-pinned, the merge rule
    content-based (ISSUE 9 acceptance)."""
    a = _two_pod_migrating(tmp_path / "p01", order=(0, 1))
    b = _two_pod_migrating(tmp_path / "p10", order=(1, 0))
    assert a[0].completed == N_RUNS and b[0].completed == N_RUNS
    _assert_dirs_identical(tmp_path / "p01", tmp_path / "p10")
    # every pod published its complete epochs; elites flowed between pods
    names = os.listdir(tmp_path / "p01")
    assert any(n.startswith("migrants_pod0_") for n in names)
    assert any(n.startswith("migrants_pod1_") for n in names)


def test_migration_missing_peer_times_out(tmp_path):
    """An importer whose peer never published fails loudly (never silently
    skips the import — that would fork the deterministic results)."""
    # pod 0 owns plan positions {0, 1}: position 1 is epoch 1 and must wait
    # for pod 1's epoch-0 file, which no process ever writes
    with pytest.raises(RuntimeError, match="migrant file"):
        _sweep(tmp_path, pod_index=0, n_pods=2, migrate_every=1,
               migrate_timeout=0.3)


def test_migration_rejects_foreign_fingerprint(tmp_path):
    """A stale migrant file of a DIFFERENT grid sharing the directory is a
    config error, not silently-imported data."""
    mgr = migrate_mod.MigrationManager(str(tmp_path), pod=1, pod_lens=[2, 2],
                                       period=1, fingerprint="aaaa")
    mgr.maybe_publish(0, {"sigma": np.zeros((0,), np.float32),
                          "nodes": np.zeros((0, 4, 3), np.int32),
                          "outs": np.zeros((0, 2), np.int32),
                          "power_rel": np.zeros((0,), np.float32),
                          "digest": np.zeros((0, 16), np.uint8)})
    # pod_lens [0, 2]: only pod 1 publishes epoch 0, so the reader goes
    # straight to the stale file instead of waiting on a pod-0 one
    reader = migrate_mod.MigrationManager(str(tmp_path), pod=0,
                                          pod_lens=[0, 2], period=1,
                                          fingerprint="bbbb", timeout=1.0)
    with pytest.raises(ValueError, match="fingerprint"):
        reader.candidates(0, 0.0)

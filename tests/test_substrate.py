"""Substrate: data pipeline, optimizers, checkpointing, fault runtime."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare image: deterministic property-test fallback
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, PrefetchLoader, pack_documents, synth_batch
from repro.optim import OptConfig, apply_gradients, init_opt_state
from repro.optim.schedule import lr_at
from repro.optim import compress
from repro.runtime.fault import (HeartbeatMonitor, StragglerDetector,
                                 TrainGuard, retry)


# ----------------------------- data -----------------------------------------

def test_synth_batch_deterministic():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4, seed=7)
    a = synth_batch(cfg, 3)
    b = synth_batch(cfg, 3)
    assert (a["tokens"] == b["tokens"]).all()
    c = synth_batch(cfg, 4)
    assert (a["tokens"] != c["tokens"]).any()          # steps differ
    d = synth_batch(DataConfig(vocab=1000, seq_len=16, global_batch=4,
                               seed=8), 3)
    assert (a["tokens"] != d["tokens"]).any()          # seeds differ


def test_synth_batch_host_slice_consistent():
    cfg = DataConfig(vocab=512, seq_len=8, global_batch=8)
    full = synth_batch(cfg, 0)["tokens"]
    part = synth_batch(cfg, 0, host_slice=slice(2, 5))["tokens"]
    assert (part == full[2:5]).all()


def test_synth_batch_zipf_shape_and_range():
    cfg = DataConfig(vocab=128, seq_len=64, global_batch=4, n_codebooks=4)
    b = synth_batch(cfg, 0)
    assert b["tokens"].shape == (4, 64, 4)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
    # zipf-ish: low ids should dominate
    counts = np.bincount(b["tokens"].reshape(-1), minlength=128)
    assert counts[:16].sum() > counts[64:].sum()


def test_prefetch_loader():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    loader = PrefetchLoader(cfg, start_step=0)
    b0 = next(loader)
    b1 = next(loader)
    loader.close()
    assert (b0["tokens"] == synth_batch(cfg, 0)["tokens"]).all()
    assert (b1["tokens"] == synth_batch(cfg, 1)["tokens"]).all()


def test_prefetch_loader_stops_after_close():
    """Regression (ISSUE 7): ``__next__`` used to block forever on a closed
    loader (worker stopped, queue drained).  A closed loader drains what was
    already queued, then raises StopIteration instead of hanging."""
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    loader = PrefetchLoader(cfg, start_step=0)
    first = next(loader)
    assert (first["tokens"] == synth_batch(cfg, 0)["tokens"]).all()
    loader.close()
    t0 = time.monotonic()
    drained = list(loader)  # terminates: StopIteration once the queue empties
    assert time.monotonic() - t0 < 5.0
    assert len(drained) <= 2  # at most the queue depth was buffered
    with pytest.raises(StopIteration):
        next(loader)


@given(st.lists(st.lists(st.integers(0, 250), min_size=0, max_size=40),
                min_size=1, max_size=10),
       st.integers(4, 32))
@settings(max_examples=10, deadline=None)
def test_pack_documents_preserves_stream(docs, seq_len):
    eos = 255
    out = pack_documents(docs, seq_len, eos)
    flat = []
    for d in docs:
        flat.extend(d)
        flat.append(eos)
    got = out.reshape(-1)[:len(flat)]
    assert (got == np.asarray(flat, np.int32)[:got.size]).all()
    assert out.shape[1] == seq_len


# ----------------------------- optimizers -----------------------------------

def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss_fn, target


@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor"])
def test_optimizers_descend_quadratic(name):
    params, loss_fn, target = _quadratic_problem()
    cfg = OptConfig(name=name, lr=0.05, weight_decay=0.0, warmup_steps=1,
                    total_steps=200)
    state = init_opt_state(params, cfg)
    l0 = float(loss_fn(params))
    for s in range(60):
        grads = jax.grad(loss_fn)(params)
        params, state = apply_gradients(params, grads, state, jnp.int32(s),
                                        cfg)
    assert float(loss_fn(params)) < 0.2 * l0, name


def test_adamw8bit_tracks_fp_adamw():
    params, loss_fn, _ = _quadratic_problem()
    cfg_a = OptConfig(name="adamw", lr=0.05, weight_decay=0.0,
                      warmup_steps=1, total_steps=100)
    cfg_b = OptConfig(name="adamw8bit", lr=0.05, weight_decay=0.0,
                      warmup_steps=1, total_steps=100)
    pa, sa = dict(params), init_opt_state(params, cfg_a)
    pb, sb = dict(params), init_opt_state(params, cfg_b)
    for s in range(20):
        ga = jax.grad(loss_fn)(pa)
        gb = jax.grad(loss_fn)(pb)
        pa, sa = apply_gradients(pa, ga, sa, jnp.int32(s), cfg_a)
        pb, sb = apply_gradients(pb, gb, sb, jnp.int32(s), cfg_b)
    # 8-bit moments track the fp32 trajectory closely on a smooth problem
    np.testing.assert_allclose(np.asarray(pb["w"]), np.asarray(pa["w"]),
                               rtol=0.1, atol=0.05)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    lrs = [float(lr_at(s, cfg)) for s in range(100)]
    assert lrs[0] < 0.2                      # warmup starts low
    assert abs(max(lrs) - 1.0) < 0.01        # reaches peak
    assert lrs[-1] < 0.2                     # decays
    assert lrs[-1] >= 0.099                  # floor respected


def test_grad_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(300,)).astype(np.float32))}
    err = compress.init_error(g_true)
    acc = np.zeros(300, np.float32)
    n = 50
    for _ in range(n):
        qt, err = compress.compress_with_feedback(g_true, err)
        deq = compress.dequantize_leaf(qt["w"]["q"], qt["w"]["s"], (300,))
        acc += np.asarray(deq)
    # error feedback => time-average converges to the true gradient
    np.testing.assert_allclose(acc / n, np.asarray(g_true["w"]),
                               rtol=0.02, atol=0.005)


# ----------------------------- checkpoint -----------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    store.save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
    assert store.latest_step(str(tmp_path)) == 7
    out, meta = store.load_checkpoint(str(tmp_path), 7, tree)
    assert meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_incomplete_ignored(tmp_path):
    tree = {"a": jnp.ones((2,))}
    p = store.save_checkpoint(str(tmp_path), 3, tree)
    # simulate a torn write at step 5
    os.makedirs(tmp_path / "step_00000005")
    (tmp_path / "step_00000005" / "manifest.json").write_text("{}")
    assert store.latest_step(str(tmp_path)) == 3


def test_checkpoint_async_and_cleanup(tmp_path):
    ck = store.AsyncCheckpointer()
    tree = {"a": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        ck.save(str(tmp_path), s, tree)
    ck.wait()
    store.cleanup(str(tmp_path), keep=2)
    assert store.latest_step(str(tmp_path)) == 4
    remaining = sorted(os.listdir(tmp_path))
    assert len([d for d in remaining if d.startswith("step_")]) == 2


# ----------------------------- runtime --------------------------------------

def test_heartbeat_deadline():
    t = {"now": 0.0}
    mon = HeartbeatMonitor(deadline_s=10.0, clock=lambda: t["now"])
    mon.beat("h0")
    mon.beat("h1")
    t["now"] = 5.0
    assert mon.dead_hosts() == []
    t["now"] = 11.0
    mon.beat("h1")
    assert mon.dead_hosts() == ["h0"]


def test_straggler_detection():
    det = StragglerDetector(alpha=1.0, threshold=1.5, patience=2)
    flagged = []
    for step in range(5):
        det.observe("fast0", 1.0)
        det.observe("fast1", 1.1)
        flagged = det.observe("slow", 3.0)
    assert flagged == ["slow"]


def test_retry_transient():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 42

    assert retry(flaky, retries=5, sleep=lambda s: None) == 42
    assert calls["n"] == 3
    with pytest.raises(OSError):
        retry(lambda: (_ for _ in ()).throw(OSError("x")).__next__(),
              retries=1, sleep=lambda s: None)


def test_train_guard_integration():
    t = {"now": 0.0}
    failures = []
    guard = TrainGuard(
        HeartbeatMonitor(deadline_s=5.0, clock=lambda: t["now"]),
        StragglerDetector(alpha=1.0, threshold=1.5, patience=1),
        on_failure=failures.append)
    guard.step("h0", 1.0)
    status = guard.step("h1", 1.0)
    assert status["dead"] == [] and status["stragglers"] == []

"""Distributed correctness on fake multi-device meshes (subprocesses so the
main test process keeps its single real device, per the task brief)."""
import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_distributed_lm_matches_single_device():
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, LayerSpec, MoEConfig, SSMConfig
from repro.models import model as M
from repro.parallel import ctx
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
cfg = ModelConfig(name='hyb', n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=256,
                  period=(LayerSpec(kind='ssm'), LayerSpec(kind='attn', moe=True)),
                  ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                  moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                capacity_factor=2.0), remat=True)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
toks = jax.random.randint(key, (8, 32), 0, cfg.vocab)
ref, _ = M.forward_train(params, toks, cfg)
with ctx.use_mesh(mesh):
    shardings = ctx.map_specs(lambda s: ctx.named_sharding(tuple(s)),
                              M.param_specs(cfg))
    p_sh = jax.device_put(params, shardings)
    t_sh = jax.device_put(toks, ctx.named_sharding(('dp', None)))
    got, _ = jax.jit(lambda p, t: M.forward_train(p, t, cfg))(p_sh, t_sh)
err = np.abs(np.asarray(got) - np.asarray(ref)).max()
assert err < 1e-3, err
print('DIST-LM-OK', err)
""")
    assert "DIST-LM-OK" in out


@pytest.mark.slow
def test_evolve_sharded_runs_and_improves():
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.core import golden as G, simulate as S, metrics as MM
from repro.core.evolve import EvolveConfig, evolve_sharded, make_island_keys
from repro.core.fitness import ConstraintSpec
from repro.core.power import circuit_cost_from_probs
from repro.parallel import ctx
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
gold, spec = G.array_multiplier(4, n_n=120)
planes = S.input_planes(spec.n_i)
gvals = jnp.asarray(G.golden_values(4, 'mul'))
wires = S.simulate_planes(gold, spec, planes)
probs = S.signal_probabilities(wires[spec.n_i:], spec.n_inputs_total)
gpower = circuit_cost_from_probs(gold, spec, probs).power
cfg = EvolveConfig(generations=150, lam=4, migrate_every=32)
# two pods = two different constraint configurations (the paper's sweep)
thr = jnp.stack([jnp.asarray(ConstraintSpec(mae=2.0).thresholds()),
                 jnp.asarray(ConstraintSpec(mae=0.5, er=60.0).thresholds())])
keys = make_island_keys(0, 4)  # data axis: 2 pods x 2 islands... 4 islands total? no: data=2 -> 2 per pod
keys = make_island_keys(0, 2)
with ctx.use_mesh(mesh):
    fn = evolve_sharded(mesh, spec, cfg, gold, thr, gpower, pod_axis='pod')
    parent, best, best_fit, hp, hm, hf = jax.jit(fn)(thr, keys, planes, gvals)
hp = np.asarray(hp)
assert hp.shape == (2, cfg.generations)
assert np.isfinite(hp).all()
assert (hp[:, -1] <= 1.0 + 1e-6).all()
print('DIST-EVOLVE-OK', hp[:, -1])
""")
    assert "DIST-EVOLVE-OK" in out


@pytest.mark.slow
def test_debug_mesh_dryrun_cell():
    """A miniature dry-run on an in-test mesh proves the dryrun plumbing
    (shardings + lowering + collective parsing) without 512 devices."""
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp
from repro.configs import base as B
from repro.launch import steps as ST
from repro.launch.dryrun import parse_collective_bytes
from repro.models import model as M
from repro.optim import OptConfig, opt_state_specs
from repro.parallel import ctx
mesh = jax.make_mesh((2, 4), ('data', 'model'))
mod = B.get_arch('llama3_2_1b')
cfg = mod.reduced()
import dataclasses
cfg = dataclasses.replace(cfg, scan_layers=True)
shape = B.ShapeConfig('t', 64, 4, 'train')
opt_cfg = OptConfig()
with ctx.use_mesh(mesh):
    params_sds = ST.abstract_params(cfg)
    opt_sds = ST.abstract_opt_state(cfg, opt_cfg)
    pspecs = ST.resolve_tree(M.param_specs(cfg))
    ospecs = ST.resolve_tree(opt_state_specs(M.param_specs(cfg), opt_cfg))
    bspecs = ST.resolve_tree(ST.batch_specs(cfg, shape))
    batch = B.input_specs(cfg, shape)
    step = ST.make_train_step(cfg, opt_cfg)
    jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs, None),
                     out_shardings=(pspecs, ospecs, None),
                     donate_argnums=(0, 1))
    lowered = jitted.lower(params_sds, opt_sds, batch,
                           jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
    colls = parse_collective_bytes(compiled.as_text(), {'default': 1})
    assert colls['total_bytes'] > 0, 'expected collectives on a 2x4 mesh'
    print('DRYRUN-MINI-OK', sorted(colls['per_op']))
""")
    assert "DRYRUN-MINI-OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save on mesh A (2x4), restore on mesh B (4x2) — elastic rescale."""
    out = run_subprocess("""
import sys, tempfile; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import store
mesh_a = jax.make_mesh((2, 4), ('data', 'model'))
mesh_b = jax.make_mesh((4, 2), ('data', 'model'))
x = jnp.arange(64.0).reshape(8, 8)
tree = {'w': jax.device_put(x, NamedSharding(mesh_a, P('data', 'model')))}
d = tempfile.mkdtemp()
store.save_checkpoint(d, 1, tree)
tmpl = {'w': jax.ShapeDtypeStruct((8, 8), jnp.float32)}
shard_b = {'w': NamedSharding(mesh_b, P('model', 'data'))}
out, _ = store.load_checkpoint(d, 1, tmpl, shard_b)
assert (np.asarray(out['w']) == np.asarray(x)).all()
assert out['w'].sharding.mesh.shape['data'] == 4
print('ELASTIC-OK')
""")
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_compressed_psum_collective():
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import compress
mesh = jax.make_mesh((8,), ('data',))
g = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0
e = jnp.zeros((8, 64), jnp.float32)

def local(g_l, e_l):
    red, err = compress.compressed_psum({'w': g_l[0]}, {'w': e_l[0]}, 'data')
    return red['w'][None], err['w'][None]

fn = shard_map(local, mesh=mesh, in_specs=(P('data'), P('data')),
               out_specs=(P('data'), P('data')), check_rep=False)
red, err = fn(g, e)
want = g.mean(axis=0)
got = np.asarray(red[0])
np.testing.assert_allclose(got, np.asarray(want), rtol=0.02, atol=0.01)
print('COMPRESS-PSUM-OK')
""", devices=8)
    assert "COMPRESS-PSUM-OK" in out

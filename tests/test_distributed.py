"""Distributed correctness on fake multi-device meshes (subprocesses so the
main test process keeps its single real device, per the task brief)."""
import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_distributed_lm_matches_single_device():
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, LayerSpec, MoEConfig, SSMConfig
from repro.models import model as M
from repro.parallel import ctx
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
cfg = ModelConfig(name='hyb', n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=256,
                  period=(LayerSpec(kind='ssm'), LayerSpec(kind='attn', moe=True)),
                  ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                  moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                                capacity_factor=2.0), remat=True)
key = jax.random.PRNGKey(0)
params = M.init_params(key, cfg)
toks = jax.random.randint(key, (8, 32), 0, cfg.vocab)
ref, _ = M.forward_train(params, toks, cfg)
with ctx.use_mesh(mesh):
    shardings = ctx.map_specs(lambda s: ctx.named_sharding(tuple(s)),
                              M.param_specs(cfg))
    p_sh = jax.device_put(params, shardings)
    t_sh = jax.device_put(toks, ctx.named_sharding(('dp', None)))
    got, _ = jax.jit(lambda p, t: M.forward_train(p, t, cfg))(p_sh, t_sh)
err = np.abs(np.asarray(got) - np.asarray(ref)).max()
assert err < 1e-3, err
print('DIST-LM-OK', err)
""")
    assert "DIST-LM-OK" in out


@pytest.mark.slow
def test_evolve_sharded_runs_and_improves():
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from repro.core import golden as G, simulate as S, metrics as MM
from repro.core.evolve import EvolveConfig, evolve_sharded, make_island_keys
from repro.core.fitness import ConstraintSpec
from repro.core.power import circuit_cost_from_probs
from repro.parallel import ctx
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
gold, spec = G.array_multiplier(4, n_n=120)
planes = S.input_planes(spec.n_i)
gvals = jnp.asarray(G.golden_values(4, 'mul'))
wires = S.simulate_planes(gold, spec, planes)
probs = S.signal_probabilities(wires[spec.n_i:], spec.n_inputs_total)
gpower = circuit_cost_from_probs(gold, spec, probs).power
cfg = EvolveConfig(generations=150, lam=4, migrate_every=32)
# two pods = two different constraint configurations (the paper's sweep)
thr = jnp.stack([jnp.asarray(ConstraintSpec(mae=2.0).thresholds()),
                 jnp.asarray(ConstraintSpec(mae=0.5, er=60.0).thresholds())])
keys = make_island_keys(0, 4)  # data axis: 2 pods x 2 islands... 4 islands total? no: data=2 -> 2 per pod
keys = make_island_keys(0, 2)
with ctx.use_mesh(mesh):
    fn = evolve_sharded(mesh, spec, cfg, gold, thr, gpower, pod_axis='pod')
    parent, best, best_fit, hp, hm, hf = jax.jit(fn)(thr, keys, planes, gvals)
hp = np.asarray(hp)
assert hp.shape == (2, cfg.generations)
assert np.isfinite(hp).all()
assert (hp[:, -1] <= 1.0 + 1e-6).all()
print('DIST-EVOLVE-OK', hp[:, -1])
""")
    assert "DIST-EVOLVE-OK" in out


@pytest.mark.slow
def test_pod_mesh_sweep_matches_single_host():
    """ISSUE 4 acceptance: the pod-sharded sweep on a forced 2-pod CPU mesh
    produces bit-identical per-run results AND shard bytes vs the
    single-host path, including a resume from a partial per-pod shard set
    (pod 1's chunks committed, pod 0 interrupted mid-slice)."""
    out = run_subprocess("""
import sys, os, tempfile; sys.path.insert(0, 'src')
import numpy as np
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.results import SweepResultReader
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched
from repro.launch.mesh import make_sweep_mesh
from repro.parallel import ctx

CFG = SearchConfig(width=2, kind='add', n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
CONS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
        ConstraintSpec(er=50.0)]
sd, pd = tempfile.mkdtemp(), tempfile.mkdtemp()
single = run_sweep_batched(CFG, CONS, (0, 1), SweepConfig(
    chunk_size=2, keep_history='summary', results_dir=sd))
assert single.completed == 6
mesh = make_sweep_mesh(pods=2)
with ctx.use_mesh(mesh):
    pods = ctx.pod_count()
    assert pods == 2, pods
    # one process drives both pod slices in turn (multi-host runs one of
    # these per host); pod 0 is interrupted first to leave a partial
    # per-pod shard set with a global gap, then both slices drain
    for pod, kw in ((0, dict(max_chunks=1)), (1, {}), (0, {})):
        res = run_sweep_batched(CFG, CONS, (0, 1), SweepConfig(
            chunk_size=2, keep_history='summary', results_dir=pd,
            n_pods=pods, pod_index=pod, **kw))
assert res.completed == 6 and res.done_mask.all()
shards = sorted(f for f in os.listdir(sd) if f.startswith('shard_'))
assert shards == sorted(f for f in os.listdir(pd)
                        if f.startswith('shard_'))
for f in shards:
    a = open(os.path.join(sd, f), 'rb').read()
    b = open(os.path.join(pd, f), 'rb').read()
    assert a == b, f'shard bytes differ: {f}'
ra, rb = SweepResultReader(sd), SweepResultReader(pd)
sa, sb = ra.summary(), rb.summary()
for key in sa:
    np.testing.assert_array_equal(sa[key], sb[key])
print('POD-SWEEP-OK', len(shards))
""", devices=2)
    assert "POD-SWEEP-OK" in out


@pytest.mark.slow
def test_pod_mesh_migration_pod_order_independent():
    """ISSUE 9 acceptance: island migration on a forced 2-pod CPU mesh is
    deterministic and pod-start-order independent — both interleavings of
    the two pod slices converge to byte-identical shard AND migrant files
    (the import schedule is pinned by the chunk plan, the merge rule is
    content-based)."""
    out = run_subprocess("""
import sys, os, tempfile; sys.path.insert(0, 'src')
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched
from repro.launch.mesh import make_sweep_mesh
from repro.parallel import ctx

CFG = SearchConfig(width=2, kind='add', n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
CONS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
        ConstraintSpec(er=50.0)]
N_RUNS = 6  # chunk_size 2 -> 3 chunks, pod slices [2, 1]

def drive(d, order):
    # one epoch (max_chunks == migrate_every) per leg, pods alternating —
    # the single-process stand-in for concurrently progressing pods
    done = {}
    for _ in range(4):
        for pod in order:
            done[pod] = run_sweep_batched(CFG, CONS, (0, 1), SweepConfig(
                chunk_size=2, keep_history='summary', results_dir=d,
                n_pods=2, pod_index=pod, max_chunks=1, migrate_every=1,
                migrate_timeout=30.0))
            if len(done) == 2 and all(
                    r.completed == N_RUNS for r in done.values()):
                return done[pod]
    raise AssertionError('pods never drained: %r' %
                         {p: r.completed for p, r in done.items()})

da, db = tempfile.mkdtemp(), tempfile.mkdtemp()
mesh = make_sweep_mesh(pods=2)
with ctx.use_mesh(mesh):
    last = drive(da, (0, 1))
    drive(db, (1, 0))
assert last.migrate_stats is not None
files = sorted(f for f in os.listdir(da)
               if f.startswith(('shard_', 'migrants_')))
assert files == sorted(f for f in os.listdir(db)
                       if f.startswith(('shard_', 'migrants_')))
assert any(f.startswith('migrants_pod0_') for f in files)
assert any(f.startswith('migrants_pod1_') for f in files)
for f in files:
    a = open(os.path.join(da, f), 'rb').read()
    b = open(os.path.join(db, f), 'rb').read()
    assert a == b, f'bytes differ across pod orders: {f}'
print('MIGRATE-MESH-OK', len(files))
""", devices=2)
    assert "MIGRATE-MESH-OK" in out


@pytest.mark.slow
def test_model_sharded_sweep_dispatch_matches_unsharded():
    """SweepConfig.model_axis: the (chunk × λ) dispatch with the input cube
    shard_map'd over the model axis (evaluation partials psum through the
    cube-shard kernel variant) is bit-identical to the unsharded dispatch —
    for BOTH backends; the pallas leg exercises the fused batched kernel
    under sharding, which used to fall back to a per-genome vmap."""
    out = run_subprocess("""
import sys, dataclasses; sys.path.insert(0, 'src')
import numpy as np
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched
from repro.launch.mesh import make_sweep_mesh
from repro.parallel import ctx

CFG = SearchConfig(width=3, kind='mul', n_n=60,
                   evolve=EvolveConfig(generations=30, lam=3))
CONS = [ConstraintSpec(mae=2.0), ConstraintSpec(er=50.0)]
plain = run_sweep_batched(CFG, CONS, (0, 1), SweepConfig(chunk_size=3))
mesh = make_sweep_mesh(pods=1)  # (1, 1, 2): both devices on model
for backend in ('jnp', 'pallas'):
    cfg = dataclasses.replace(
        CFG, evolve=dataclasses.replace(CFG.evolve, backend=backend))
    with ctx.use_mesh(mesh):
        sharded = run_sweep_batched(cfg, CONS, (0, 1), SweepConfig(
            chunk_size=3, model_axis='model'))
    assert sharded.completed == plain.completed
    for a, b in zip(plain.records, sharded.records):
        assert (a.genome_nodes == b.genome_nodes).all(), backend
        assert (a.genome_outs == b.genome_outs).all(), backend
        np.testing.assert_array_equal(a.metrics, b.metrics)
    np.testing.assert_array_equal(plain.hist_fit, sharded.hist_fit)
print('MODEL-SHARD-SWEEP-OK')
""", devices=2)
    assert "MODEL-SHARD-SWEEP-OK" in out


@pytest.mark.slow
def test_debug_mesh_dryrun_cell():
    """A miniature dry-run on an in-test mesh proves the dryrun plumbing
    (shardings + lowering + collective parsing) without 512 devices."""
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp
from repro.configs import base as B
from repro.launch import steps as ST
from repro.launch.dryrun import parse_collective_bytes
from repro.models import model as M
from repro.optim import OptConfig, opt_state_specs
from repro.parallel import ctx
mesh = jax.make_mesh((2, 4), ('data', 'model'))
mod = B.get_arch('llama3_2_1b')
cfg = mod.reduced()
import dataclasses
cfg = dataclasses.replace(cfg, scan_layers=True)
shape = B.ShapeConfig('t', 64, 4, 'train')
opt_cfg = OptConfig()
with ctx.use_mesh(mesh):
    params_sds = ST.abstract_params(cfg)
    opt_sds = ST.abstract_opt_state(cfg, opt_cfg)
    pspecs = ST.resolve_tree(M.param_specs(cfg))
    ospecs = ST.resolve_tree(opt_state_specs(M.param_specs(cfg), opt_cfg))
    bspecs = ST.resolve_tree(ST.batch_specs(cfg, shape))
    batch = B.input_specs(cfg, shape)
    step = ST.make_train_step(cfg, opt_cfg)
    jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs, None),
                     out_shardings=(pspecs, ospecs, None),
                     donate_argnums=(0, 1))
    lowered = jitted.lower(params_sds, opt_sds, batch,
                           jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
    colls = parse_collective_bytes(compiled.as_text(), {'default': 1})
    assert colls['total_bytes'] > 0, 'expected collectives on a 2x4 mesh'
    print('DRYRUN-MINI-OK', sorted(colls['per_op']))
""")
    assert "DRYRUN-MINI-OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save on mesh A (2x4), restore on mesh B (4x2) — elastic rescale."""
    out = run_subprocess("""
import sys, tempfile; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import store
mesh_a = jax.make_mesh((2, 4), ('data', 'model'))
mesh_b = jax.make_mesh((4, 2), ('data', 'model'))
x = jnp.arange(64.0).reshape(8, 8)
tree = {'w': jax.device_put(x, NamedSharding(mesh_a, P('data', 'model')))}
d = tempfile.mkdtemp()
store.save_checkpoint(d, 1, tree)
tmpl = {'w': jax.ShapeDtypeStruct((8, 8), jnp.float32)}
shard_b = {'w': NamedSharding(mesh_b, P('model', 'data'))}
out, _ = store.load_checkpoint(d, 1, tmpl, shard_b)
assert (np.asarray(out['w']) == np.asarray(x)).all()
assert out['w'].sharding.mesh.shape['data'] == 4
print('ELASTIC-OK')
""")
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_compressed_psum_collective():
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim import compress
mesh = jax.make_mesh((8,), ('data',))
g = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0
e = jnp.zeros((8, 64), jnp.float32)

def local(g_l, e_l):
    red, err = compress.compressed_psum({'w': g_l[0]}, {'w': e_l[0]}, 'data')
    return red['w'][None], err['w'][None]

fn = shard_map(local, mesh=mesh, in_specs=(P('data'), P('data')),
               out_specs=(P('data'), P('data')), check_rep=False)
red, err = fn(g, e)
want = g.mean(axis=0)
got = np.asarray(red[0])
np.testing.assert_allclose(got, np.asarray(want), rtol=0.02, atol=0.01)
print('COMPRESS-PSUM-OK')
""", devices=8)
    assert "COMPRESS-PSUM-OK" in out

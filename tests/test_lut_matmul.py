"""LUT-matmul deployment path + circuit-artifact registry (DESIGN.md §12).

The serving-side contract, in three layers:

  * kernel fidelity — under the EXACT product LUT, ``kernels.ops.lut_matmul``
    must be bit-identical to a plain int32 matmul for any (M, N, K),
    including ragged shapes the wrapper pads; under an APPROXIMATE LUT it
    must match a NumPy gather oracle bit-for-bit (the pad-bias regression:
    zero-padded contraction steps each inject ``LUT[0, 0]``, which the
    wrapper must subtract back out);
  * artifact integrity — ``export_elites``/``load_artifact`` round-trip a
    sweep's elites, and the verify path refuses corrupted payloads,
    wrong-sweep fingerprints and unverifiable directories;
  * schema compatibility — v2 (pre-certification) shard directories export
    with ``certified=0``; manifests predating the ``problem`` block need an
    explicit ``width=``.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.artifacts import (ARTIFACT_SCHEMA_VERSION, ExportPolicy,
                                  REGISTRY, content_digest, export_elites,
                                  load_artifact, load_registry,
                                  resolve_artifact, select_artifact,
                                  verify_registry)
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched
from repro.kernels import ops, ref

EXACT_LUT = (np.arange(256, dtype=np.int64)[:, None]
             * np.arange(256, dtype=np.int64)[None, :]).astype(np.int32)

#: ragged shapes cover every pad combination: M-only, K-only, N-only, all
#: three, the degenerate 1x1x1, and one evenly-tiled control
SHAPES = [(128, 128, 128), (7, 130, 5), (24, 48, 16), (130, 7, 129),
          (1, 1, 1), (33, 128, 64)]


def _rand_operands(m, n, k, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 256, (m, k), dtype=np.uint8),
            rng.integers(0, 256, (k, n), dtype=np.uint8))


def _np_oracle(a, b, lut):
    """Pure-NumPy gather contraction: C[m,n] = sum_k LUT[a[m,k], b[k,n]]."""
    prods = lut.astype(np.int64)[a.astype(np.int64)[:, :, None],
                                 b.astype(np.int64)[None, :, :]]
    return prods.sum(axis=1).astype(np.int32)


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_exact_lut_matches_int8_matmul(m, n, k):
    """With the exact product table the LUT kernel IS an integer matmul —
    bit-identical, every shape (the deploy-job sanity invariant)."""
    a, b = _rand_operands(m, n, k)
    want = np.asarray(jnp.matmul(jnp.asarray(a, jnp.int32),
                                 jnp.asarray(b, jnp.int32)))
    got = np.asarray(ops.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                    jnp.asarray(EXACT_LUT)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_approx_lut_matches_numpy_oracle(m, n, k):
    """An arbitrary (approximate) LUT contracts bit-identically to the
    NumPy gather oracle through both the kernel wrapper and the jnp ref."""
    rng = np.random.default_rng(k * 1000 + m)
    lut = (EXACT_LUT + rng.integers(-3, 4, EXACT_LUT.shape)).astype(np.int32)
    a, b = _rand_operands(m, n, k, seed=1)
    want = _np_oracle(a, b, lut)
    got_kernel = np.asarray(ops.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                           jnp.asarray(lut)))
    got_ref = np.asarray(ref.lut_matmul_ref(jnp.asarray(a), jnp.asarray(b),
                                            jnp.asarray(lut)))
    np.testing.assert_array_equal(got_kernel, want)
    np.testing.assert_array_equal(got_ref, want)


def test_ragged_k_pad_bias_regression():
    """Zero-padding K is not free when LUT[0,0] != 0: each padded step adds
    LUT[0,0] to EVERY output element.  The wrapper must subtract the bias
    (regression: pre-fix, a ragged K=5 with LUT[0,0]=7 was off by
    (bk - 5) * 7 everywhere)."""
    lut = EXACT_LUT.copy()
    lut[0, 0] = 7                       # evolved circuits need not map 0*0->0
    for m, n, k in [(3, 4, 5), (16, 16, 100), (130, 7, 129)]:
        a, b = _rand_operands(m, n, k, seed=2)
        want = _np_oracle(a, b, lut)
        got = np.asarray(ops.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                                        jnp.asarray(lut)))
        np.testing.assert_array_equal(got, want)


def test_raw_kernel_refuses_ragged_shapes():
    """The raw tiled kernel (kernels.lut_matmul) raises on uneven tiling —
    padding and bias correction live in the ops wrapper only."""
    from repro.kernels import lut_matmul as raw
    a, b = _rand_operands(7, 130, 5)
    with pytest.raises(ValueError, match="tile evenly"):
        raw.lut_matmul(jnp.asarray(a), jnp.asarray(b),
                       jnp.asarray(EXACT_LUT))
    with pytest.raises(ValueError, match="contraction"):
        raw.lut_matmul(jnp.zeros((8, 16), jnp.uint8),
                       jnp.zeros((8, 8), jnp.uint8), jnp.asarray(EXACT_LUT))


# ---------------------------------------------------------------------------
# artifact registry: a tiny real multiplier sweep, exported once per module
# ---------------------------------------------------------------------------

CFG = SearchConfig(width=2, kind="mul", n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
CONSTRAINTS = [ConstraintSpec(mae=2.0), ConstraintSpec(er=60.0)]
SEEDS = (0,)


@pytest.fixture(scope="module")
def sweep_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("shards")
    run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                      SweepConfig(chunk_size=2, keep_history="none",
                                  results_dir=str(d)))
    return str(d)


@pytest.fixture()
def registry_dir(sweep_dir, tmp_path):
    out = str(tmp_path / "registry")
    export_elites(sweep_dir, out)
    return out


def test_export_load_round_trip(sweep_dir, registry_dir):
    reg = load_registry(registry_dir)
    assert reg["schema_version"] == ARTIFACT_SCHEMA_VERSION
    assert reg["problem"] == {"width": 2, "kind": "mul", "n_n": 40}
    assert len(reg["artifacts"]) == len(CONSTRAINTS)   # top_k=1 per group
    for entry in reg["artifacts"]:
        art = load_artifact(os.path.join(registry_dir, entry["file"]),
                            expect_fingerprint=reg["grid_fingerprint"])
        assert art.width == 2 and art.kind == "mul"
        assert art.lut.shape == (4, 4) and art.lut.dtype == np.int32
        assert art.digest == entry["digest"] and len(art.digest) == 64
        assert art.feasible and art.constraint == entry["constraint"]
        assert set(art.metric_dict()) == {"mae", "wce", "er", "mre", "avg",
                                          "acc0", "gauss"}
    # full verify path: every entry's digest + genome replay + index row
    assert len(verify_registry(registry_dir)) == len(CONSTRAINTS)
    # selection picks a feasible entry; resolve accepts the directory form
    best = select_artifact(registry_dir)
    assert resolve_artifact(registry_dir).path == best
    assert resolve_artifact(best).digest == load_artifact(best).digest


def test_export_is_idempotent(sweep_dir, registry_dir):
    """Digest-named artifacts: re-exporting the same sweep rewrites the
    same files and the same registry."""
    before = load_registry(registry_dir)
    export_elites(sweep_dir, registry_dir)
    after = load_registry(registry_dir)
    assert before == after
    npzs = [f for f in os.listdir(registry_dir) if f.endswith(".npz")]
    assert sorted(npzs) == sorted(e["file"] for e in after["artifacts"])


def test_digest_mismatch_refused(registry_dir):
    """A flipped LUT byte must be refused by the digest check (and the
    registry-wide verify), not served."""
    reg = load_registry(registry_dir)
    path = os.path.join(registry_dir, reg["artifacts"][0]["file"])
    with np.load(path) as z:
        payload = {k: np.asarray(z[k]) for k in z.files}
    payload["lut"] = payload["lut"].copy()
    payload["lut"][0, 0] += 1
    np.savez(path, **payload)           # deliberately NOT atomic_save_npz
    with pytest.raises(ValueError, match="digest mismatch"):
        load_artifact(path)
    with pytest.raises(ValueError, match="digest mismatch"):
        verify_registry(registry_dir)
    # verify=False loads it anyway (forensics path), flag intact
    assert load_artifact(path, verify=False).lut[0, 0] \
        == payload["lut"][0, 0]


def test_tampered_lut_with_recomputed_digest_refused(registry_dir):
    """An attacker who re-stamps the digest after editing the LUT is still
    caught by the genome-replay check."""
    reg = load_registry(registry_dir)
    path = os.path.join(registry_dir, reg["artifacts"][0]["file"])
    with np.load(path) as z:
        payload = {k: np.asarray(z[k]) for k in z.files}
    payload["lut"] = payload["lut"].copy()
    payload["lut"][1, 1] += 1
    payload["digest"] = np.str_(content_digest(payload))
    np.savez(path, **payload)
    with pytest.raises(ValueError, match="genome replay"):
        load_artifact(path)


def test_wrong_fingerprint_refused(registry_dir):
    reg = load_registry(registry_dir)
    path = os.path.join(registry_dir, reg["artifacts"][0]["file"])
    with pytest.raises(ValueError, match="wrong sweep"):
        load_artifact(path, expect_fingerprint="0" * 64)


def test_registry_dir_collision_refused(registry_dir, tmp_path):
    """A directory holding a different grid's registry must be refused, not
    silently mixed."""
    man = load_registry(registry_dir)
    man["grid_fingerprint"] = "f" * 64
    with open(os.path.join(registry_dir, REGISTRY), "w") as f:
        json.dump(man, f)
    d = tmp_path / "other-shards"
    run_sweep_batched(CFG, CONSTRAINTS[:1], SEEDS,
                      SweepConfig(chunk_size=2, keep_history="none",
                                  results_dir=str(d)))
    with pytest.raises(ValueError, match="different sweep"):
        export_elites(str(d), registry_dir)


def test_add_sweeps_not_exportable(sweep_dir, tmp_path):
    with pytest.raises(ValueError, match="not exportable"):
        export_elites(sweep_dir, str(tmp_path / "reg"), kind="add")
    with pytest.raises(ValueError, match="contradicts"):
        export_elites(sweep_dir, str(tmp_path / "reg"), width=4)


def test_v2_shards_export_with_certified_default(sweep_dir, tmp_path):
    """Pre-§10 (v2) shard sets export fine — certified=0 on every artifact
    (the reader-side column default)."""
    import shutil
    from tests.test_results import _downgrade_to_v2
    d = str(tmp_path / "v2-shards")
    shutil.copytree(sweep_dir, d)
    _downgrade_to_v2(d)
    out = str(tmp_path / "v2-registry")
    reg = export_elites(d, out)
    assert reg["artifacts"] and all(not e["certified"]
                                    for e in reg["artifacts"])
    for art in verify_registry(out):
        assert not art.certified


def test_pre_problem_manifest_needs_explicit_width(sweep_dir, tmp_path):
    """Manifests written before the ``problem`` block: export refuses to
    guess the operand width, and accepts an explicit one."""
    import shutil
    d = str(tmp_path / "old-shards")
    shutil.copytree(sweep_dir, d)
    man_path = os.path.join(d, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    del man["problem"]
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="predates problem metadata"):
        export_elites(d, str(tmp_path / "reg"))
    reg = export_elites(d, str(tmp_path / "reg"), width=2)
    assert reg["problem"]["width"] == 2


def test_require_certified_policy(sweep_dir, tmp_path):
    """require_certified on an exhaustively-certified width-2 sweep keeps
    every elite; feasible_only=False admits infeasible rows too."""
    out = str(tmp_path / "cert-reg")
    reg = export_elites(sweep_dir, out,
                        ExportPolicy(require_certified=True))
    assert all(e["certified"] for e in reg["artifacts"])
    reg_all = export_elites(sweep_dir, str(tmp_path / "all-reg"),
                            ExportPolicy(top_k=8, feasible_only=False))
    assert len(reg_all["artifacts"]) >= len(reg["artifacts"])

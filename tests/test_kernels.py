"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import golden as G, simulate as S
from repro.core.genome import CGPSpec, random_genome
from repro.kernels import ops, ref


# ----------------------------- cgp_sim --------------------------------------

def _assert_partials_close(pk, pr, rtol=1e-5):
    for name in pk._fields:
        a, b = np.asarray(getattr(pk, name)), np.asarray(getattr(pr, name))
        np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-6,
                                   err_msg=f"partial {name}")


@pytest.mark.parametrize("width,n_n,block", [(3, 80, 2), (4, 120, 8),
                                             (4, 120, 4), (5, 200, 32)])
def test_cgp_kernel_matches_ref_random(width, n_n, block):
    spec = CGPSpec(n_i=2 * width, n_o=2 * width, n_n=n_n)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    for seed in range(3):
        g = random_genome(jax.random.PRNGKey(seed), spec)
        pk, popk = ops.cgp_eval(g, spec, planes, gvals, gauss_sigma=32.0,
                                block_words=block)
        pr, popr = ref.cgp_eval_ref(g, spec, planes, gvals, 32.0)
        _assert_partials_close(pk, pr)
        np.testing.assert_allclose(np.asarray(popk), np.asarray(popr))


def test_cgp_kernel_exact_multiplier_8bit():
    g, spec = G.array_multiplier(8, n_n=400)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(8, "mul"))
    pk, _ = ops.cgp_eval(g, spec, planes, gvals)
    assert float(pk.abs_sum) == 0 and int(pk.wce_max) == 0
    assert int(pk.err_count) == 0 and int(pk.acc0_bad) == 0
    assert int(pk.count) == 65536


def test_cgp_kernel_vmaps_over_population():
    spec = CGPSpec(n_i=8, n_o=8, n_n=60)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(4, "mul"))
    genomes = jax.vmap(lambda k: random_genome(k, spec))(
        jax.random.split(jax.random.PRNGKey(0), 4))
    pk, popk = jax.vmap(
        lambda g: ops.cgp_eval(g, spec, planes, gvals))(genomes)
    for i in range(4):
        gi = jax.tree.map(lambda x: x[i], genomes)
        pr, popr = ref.cgp_eval_ref(gi, spec, planes, gvals, 256.0)
        np.testing.assert_allclose(np.asarray(pk.abs_sum[i]),
                                   np.asarray(pr.abs_sum), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(popk[i]), np.asarray(popr))


# ----------------------------- lut_matmul -----------------------------------

EXACT_LUT = (np.arange(256)[:, None] * np.arange(256)[None, :]).astype(
    np.int32)


@pytest.mark.parametrize("shape", [(8, 16, 8), (128, 128, 128), (7, 130, 5),
                                   (1, 8, 1), (33, 64, 96)])
def test_lut_matmul_exact_lut_equals_int_matmul(shape):
    Mx, K, N = shape
    key = jax.random.PRNGKey(Mx * 1000 + K)
    a = jax.random.randint(key, (Mx, K), 0, 256, dtype=jnp.int32)
    b = jax.random.randint(jax.random.fold_in(key, 1), (K, N), 0, 256,
                           dtype=jnp.int32)
    got = np.asarray(ops.lut_matmul(a, b, jnp.asarray(EXACT_LUT)))
    want = np.asarray(a) @ np.asarray(b)
    assert (got == want).all()


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.uint8, jnp.int8])
def test_lut_matmul_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    hi = 127 if dtype == jnp.int8 else 255
    a = jax.random.randint(key, (16, 32), 0, hi + 1, jnp.int32).astype(dtype)
    b = jax.random.randint(jax.random.fold_in(key, 1), (32, 8), 0, hi + 1,
                           jnp.int32).astype(dtype)
    got = np.asarray(ops.lut_matmul(a, b, jnp.asarray(EXACT_LUT)))
    want = np.asarray(ref.lut_matmul_ref(a, b, jnp.asarray(EXACT_LUT)))
    assert (got == want).all()


def test_lut_matmul_approximate_lut_matches_ref():
    rng = np.random.default_rng(0)
    lut = EXACT_LUT + rng.integers(-8, 8, EXACT_LUT.shape)  # noisy circuit
    key = jax.random.PRNGKey(3)
    a = jax.random.randint(key, (24, 48), 0, 256, dtype=jnp.int32)
    b = jax.random.randint(jax.random.fold_in(key, 1), (48, 16), 0, 256,
                           dtype=jnp.int32)
    got = np.asarray(ops.lut_matmul(a, b, jnp.asarray(lut)))
    want = np.asarray(ref.lut_matmul_ref(a, b, jnp.asarray(lut)))
    assert (got == want).all()


# ----------------------------- flash attention ------------------------------

@pytest.mark.parametrize("shape", [
    (2, 4, 2, 128, 32), (1, 8, 8, 256, 64), (1, 4, 1, 64, 16),
    (2, 2, 2, 96, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_naive(shape, causal):
    B, Hq, Hkv, Ssz, D = shape
    key = jax.random.PRNGKey(B * 100 + Ssz)
    q = jax.random.normal(key, (B, Hq, Ssz, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, Ssz, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Hkv, Ssz, D))
    got = ops.flash_attention(q, k, v, causal=causal, bq=32, bkv=32)
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1).reshape(B * Hq, Ssz, D)
    vf = jnp.repeat(v, group, axis=1).reshape(B * Hq, Ssz, D)
    want = ref.attention_ref(q.reshape(B * Hq, Ssz, D), kf, vf,
                             causal=causal).reshape(B, Hq, Ssz, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 64, 16)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 16)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 16)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True, bq=32, bkv=32)
    want = ref.attention_ref(q.reshape(2, 64, 16), k.reshape(2, 64, 16),
                             v.reshape(2, 64, 16), causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32).reshape(2, 64, 16),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5, atol=2e-2)


# --------------------------------------------------------------------------
# Tuning-table cache staleness (kernels/tune.py): the stat-token cache must
# never serve a stale table after a rewrite, including same-mtime rewrites,
# and must not re-parse a corrupt table on every resolve call
# --------------------------------------------------------------------------

def _parse_counter(monkeypatch):
    from repro.kernels import tune
    calls = {"n": 0}
    real = tune.json.load

    def counting(f):
        calls["n"] += 1
        return real(f)

    monkeypatch.setattr(tune.json, "load", counting)
    return calls


def test_tune_table_cached_by_stat_token(tmp_path, monkeypatch):
    from repro.kernels import tune
    path = str(tmp_path / "table.json")
    tune.save_entry(2, 3, "cpu", {"layout": "cube_major"}, path)
    calls = _parse_counter(monkeypatch)
    first = tune.load_table(path)
    assert calls["n"] == 1
    assert tune.load_table(path) == first  # unchanged file: cache hit
    assert calls["n"] == 1
    assert first["entries"][tune.table_key(2, 3, "cpu")]["layout"] \
        == "cube_major"


def test_tune_table_same_mtime_rewrite_detected(tmp_path):
    """An atomic rewrite landing in the same mtime instant must still be
    picked up: the rename gives the file a new inode, which the stat token
    (mtime_ns, size, inode) sees even when mtime and size are unchanged."""
    import os

    from repro.kernels import tune
    path = str(tmp_path / "table.json")
    tune.save_entry(2, 3, "cpu", {"layout": "genome_major"}, path)
    assert tune.resolve_layout(2, 3, "cpu", path) == "genome_major"
    st = os.stat(path)
    # atomic rename into place (fresh inode), then pin the mtime back
    tune.save_entry(2, 3, "cpu", {"layout": "cube_major"}, path)
    os.utime(path, ns=(st.st_atime_ns, st.st_mtime_ns))
    now = os.stat(path)
    assert now.st_mtime_ns == st.st_mtime_ns  # the hostile case: mtime lies
    assert tune.resolve_layout(2, 3, "cpu", path) == "cube_major"


def test_tune_table_corrupt_is_negative_cached(tmp_path, monkeypatch):
    from repro.kernels import tune
    path = str(tmp_path / "table.json")
    with open(path, "w") as f:
        f.write("{not json")
    calls = _parse_counter(monkeypatch)
    assert tune.load_table(path) == {}
    assert tune.load_table(path) == {}  # not re-parsed per call
    assert calls["n"] == 1
    assert tune.resolve_variant(2, 3, "cpu", path) == tune.KernelVariant()
    assert calls["n"] == 1
    # a valid rewrite (new token) recovers without any cache poking
    tune.save_entry(2, 3, "cpu", {"layout": "cube_major"}, path)
    assert tune.resolve_layout(2, 3, "cpu", path) == "cube_major"


def test_tune_save_entry_invalidates_cache(tmp_path):
    from repro.kernels import tune
    path = str(tmp_path / "table.json")
    tune.save_entry(2, 3, "cpu", {"layout": "genome_major"}, path)
    assert tune.resolve_layout(2, 3, "cpu", path) == "genome_major"
    tune.save_entry(2, 8, "cpu", {"layout": "cube_major"}, path)
    table = tune.load_table(path)
    assert set(table["entries"]) == {tune.table_key(2, 3, "cpu"),
                                     tune.table_key(2, 8, "cpu")}

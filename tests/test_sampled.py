"""Sampled + distribution-weighted evaluation mode (DESIGN.md §9).

Three contracts:

  * the EXHAUSTIVE path is bit-identical to the pre-§9 engine — same input
    arrays byte-for-byte, same grid fingerprint (checkpoints/shards written
    before the sampled mode existed still resume), zero reported stderr;
  * the SAMPLED path is deterministic (pure function of the stream identity)
    and statistically sound: sampled MAE/ER land within the reported
    confidence interval of the exhaustive truth for >= 95% of sample seeds,
    and tighten as sample_size grows toward 2^(2w);
  * the mode unlocks widths the cube cannot reach: a width-12 multiplier
    evolve step completes on CPU under eval_mode="sampled" (the exhaustive
    cube would be 16.7M rows/genome).
"""
import hashlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import golden as G
from repro.core import metrics as M
from repro.core import sampling, simulate
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.genome import CGPSpec, random_genome
from repro.core.search import SearchConfig, problem_arrays
from repro.core.sweep import grid_fingerprint, sweep_grid


# ------------------------- stream determinism -----------------------------

def test_effective_sample_size_rounds_to_pow2_words():
    assert sampling.effective_sample_size(1) == 32
    assert sampling.effective_sample_size(33) == 64
    assert sampling.effective_sample_size(1000) == 1024
    assert sampling.effective_sample_size(16384) == 16384
    with pytest.raises(ValueError):
        sampling.effective_sample_size(0)


@pytest.mark.parametrize("dist", sampling.INPUT_DISTS)
def test_sampled_operands_deterministic_and_in_range(dist):
    a1, b1 = sampling.sampled_operands(6, 2048, dist, sample_seed=7)
    a2, b2 = sampling.sampled_operands(6, 2048, dist, sample_seed=7)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    a3, _ = sampling.sampled_operands(6, 2048, dist, sample_seed=8)
    assert (a1 != a3).any(), "seed must change the stream"
    assert a1.min() >= 0 and a1.max() < 64
    assert a1.shape == (2048,)
    # operand streams are disjoint — a and b are not the same draw
    assert (a1 != b1).any()


def test_stream_fingerprint_keys_every_axis():
    base = sampling.stream_fingerprint(8, 4096, "uniform", 0)
    assert base == sampling.stream_fingerprint(8, 4096, "uniform", 0)
    # nominal sizes that materialize the same rows share the fingerprint
    assert base == sampling.stream_fingerprint(8, 4000, "uniform", 0)
    for other in (sampling.stream_fingerprint(9, 4096, "uniform", 0),
                  sampling.stream_fingerprint(8, 8192, "uniform", 0),
                  sampling.stream_fingerprint(8, 4096, "gaussian", 0),
                  sampling.stream_fingerprint(8, 4096, "uniform", 1)):
        assert other != base


def test_pack_sample_planes_roundtrip():
    """Packed sample planes decode back to the operand integers with the
    exhaustive-cube bit layout (a = planes [0, w), b = planes [w, 2w))."""
    w = 5
    a, b = sampling.sampled_operands(w, 256, "uniform", sample_seed=3)
    planes = sampling.pack_sample_planes(a, b, w)
    assert planes.shape == (2 * w, len(a) // 32)
    vals = np.asarray(simulate.unpack_values(jnp.asarray(planes[:w])))
    np.testing.assert_array_equal(vals, a)
    vals_b = np.asarray(simulate.unpack_values(jnp.asarray(planes[w:])))
    np.testing.assert_array_equal(vals_b, b)


def test_golden_circuit_exact_on_sample():
    """Simulating the golden netlist on sampled planes reproduces the
    integer golden values — the sample pair is internally consistent."""
    cfg = SearchConfig(width=4, kind="mul", n_n=80,
                       evolve=EvolveConfig(eval_mode="sampled",
                                           sample_size=1024,
                                           input_dist="gaussian"))
    gold, spec, planes, gvals, _ = problem_arrays(cfg)
    cvals = simulate.simulate_values(gold, spec, planes)
    np.testing.assert_array_equal(np.asarray(cvals), np.asarray(gvals))


def test_empirical_histogram_deterministic():
    h1 = sampling.empirical_histogram(4, seed=0, n_batches=2)
    h2 = sampling.empirical_histogram(4, seed=0, n_batches=2)
    np.testing.assert_array_equal(h1, h2)
    assert h1.sum() > 0 and h1.shape == (16,)


# --------------------- exhaustive-path bit-identity -----------------------

def test_exhaustive_problem_arrays_bit_identical_to_seed():
    """eval_mode="exhaustive" (the default) builds byte-for-byte the same
    evaluation inputs as the pre-§9 direct construction."""
    cfg = SearchConfig(width=3, kind="mul", n_n=60)
    assert cfg.evolve.eval_mode == "exhaustive"
    _, spec, planes, gvals, _ = problem_arrays(cfg)
    np.testing.assert_array_equal(
        np.asarray(planes), np.asarray(simulate.input_planes(spec.n_i)))
    np.testing.assert_array_equal(
        np.asarray(gvals), G.golden_values(3, "mul"))
    assert np.asarray(planes).tobytes() == np.asarray(
        simulate.input_planes(spec.n_i)).tobytes()


def test_exhaustive_grid_fingerprint_unchanged_from_seed():
    """Exhaustive grids hash the exact pre-§9 ident dict — no eval keys —
    so checkpoints/shard manifests written before this PR still resume."""
    cfg = SearchConfig(width=3, kind="mul", n_n=60,
                       evolve=EvolveConfig(generations=50, lam=4))
    grid = sweep_grid([ConstraintSpec(mae=1.0)], (0, 1))
    ecfg = cfg.evolve
    legacy_ident = {
        "width": cfg.width, "kind": cfg.kind, "n_n": cfg.n_n,
        "generations": ecfg.generations, "lam": ecfg.lam,
        "mutation_rate": ecfg.mutation_rate, "backend": ecfg.backend,
        "migrate_every": ecfg.migrate_every,
        "keep_history": True,
        "grid": [(con.describe(), con.gauss_sigma, seed)
                 for con, seed in grid],
        "thresholds": hashlib.sha256(
            np.stack([con.thresholds() for con, _ in grid]).tobytes()
        ).hexdigest(),
    }
    legacy = hashlib.sha256(json.dumps(
        legacy_ident, sort_keys=True, default=float).encode()).hexdigest()
    assert grid_fingerprint(cfg, grid, "full") == legacy

    scfg = SearchConfig(width=3, kind="mul", n_n=60,
                        evolve=EvolveConfig(generations=50, lam=4,
                                            eval_mode="sampled"))
    assert grid_fingerprint(scfg, grid, "full") != legacy


def test_sampled_fingerprint_tracks_stream_identity():
    def fp(**kw):
        cfg = SearchConfig(width=4, kind="mul", n_n=80,
                           evolve=EvolveConfig(generations=10, lam=2,
                                               eval_mode="sampled", **kw))
        return grid_fingerprint(cfg, sweep_grid([ConstraintSpec(mae=1.0)],
                                                (0,)), "none")
    base = fp()
    assert base == fp()
    assert fp(sample_seed=1) != base
    assert fp(sample_size=1 << 15) != base
    assert fp(input_dist="gaussian") != base


def test_evolve_config_validation():
    with pytest.raises(ValueError):
        EvolveConfig(eval_mode="bogus")
    with pytest.raises(ValueError):
        EvolveConfig(input_dist="bogus")
    with pytest.raises(ValueError):
        EvolveConfig(sample_size=0)


# ----------------------- CI / convergence property ------------------------

def _metric_pair(gvals, cvals, n_o, sampled):
    p = M.error_partials(jnp.asarray(gvals), jnp.asarray(cvals), 256.0,
                         n_bits=n_o)
    met = np.asarray(M.finalize_metrics(p, n_o, 256.0))
    se = np.asarray(M.metric_stderr(p, n_o)) if sampled else None
    return met, se


def test_sampled_metrics_converge_to_exhaustive_within_ci():
    """Property (ISSUE 7): sampled MAE/ER land inside the reported
    z=2.576 (99%) confidence interval of the exhaustive truth for >= 95%
    of sample seeds, and the CI tightens as sample_size grows."""
    w, n_n = 4, 80
    spec = CGPSpec(n_i=2 * w, n_o=2 * w, n_n=n_n)
    genome = random_genome(jax.random.PRNGKey(5), spec)
    # exhaustive truth
    full_planes = simulate.input_planes(spec.n_i)
    gvals_full = G.golden_values(w, "mul")
    cvals_full = np.asarray(simulate.simulate_values(genome, spec,
                                                     full_planes))
    truth, _ = _metric_pair(gvals_full, cvals_full, spec.n_o, sampled=False)

    z = 2.576
    n_seeds = 20
    mae_devs = {}
    for size in (512, 4096):
        covered = 0
        devs = []
        for seed in range(n_seeds):
            planes, gvals = sampling.sample_problem(w, "mul", size,
                                                    "uniform", seed)
            cvals = np.asarray(simulate.simulate_values(
                genome, spec, jnp.asarray(planes)))
            met, se = _metric_pair(gvals, cvals, spec.n_o, sampled=True)
            ok = True
            for m in (M.MAE, M.ER):
                half = z * max(float(se[m]), 1e-9)
                ok &= abs(float(met[m]) - float(truth[m])) <= half
            covered += ok
            devs.append(abs(float(met[M.MAE]) - float(truth[M.MAE])))
        assert covered / n_seeds >= 0.95, \
            f"size {size}: only {covered}/{n_seeds} seeds inside the CI"
        mae_devs[size] = float(np.mean(devs))
    # convergence toward the exhaustive truth as sample_size -> 2^(2w)
    assert mae_devs[4096] < mae_devs[512]


def test_stderr_matches_numpy_oracle():
    rng = np.random.default_rng(11)
    g = rng.integers(0, 255, size=2048).astype(np.int32)
    c = np.clip(g - rng.integers(0, 9, size=2048), 0, None).astype(np.int32)
    p = M.error_partials(jnp.asarray(g), jnp.asarray(c), 256.0, n_bits=8)
    se = np.asarray(M.metric_stderr(p, 8))
    se_np = M.metrics_stderr_np(g, c, 8)
    np.testing.assert_allclose(se, se_np, rtol=1e-4, atol=1e-7)
    # extreme-value / indicator metrics report no CLT interval
    assert se[M.WCE] == 0 and se[M.ACC0] == 0 and se[M.GAUSS] == 0


def test_sampled_partials_combine_like_cube_shards():
    """Sample shards reuse the cube-shard psum/pmax contract unchanged:
    combining per-shard partials (incl. the new second-moment rows) equals
    the unsharded partials of the concatenated sample."""
    planes, gvals = sampling.sample_problem(4, "mul", 2048, "uniform", 0)
    spec = CGPSpec(n_i=8, n_o=8, n_n=60)
    genome = random_genome(jax.random.PRNGKey(2), spec)
    cvals = np.asarray(simulate.simulate_values(genome, spec,
                                                jnp.asarray(planes)))
    whole = M.error_partials(jnp.asarray(gvals), jnp.asarray(cvals), 256.0,
                             n_bits=8)
    half = len(gvals) // 2
    shards = [M.error_partials(jnp.asarray(gvals[i:j]),
                               jnp.asarray(cvals[i:j]), 256.0, n_bits=8)
              for i, j in ((0, half), (half, len(gvals)))]
    for name in M.MetricPartials._fields:
        a, b = (getattr(s, name) for s in shards)
        comb = np.maximum(a, b) if name == "wce_max" else a + b
        np.testing.assert_allclose(np.asarray(comb),
                                   np.asarray(getattr(whole, name)),
                                   rtol=1e-6,
                                   err_msg=f"shard combine: {name}")


# ----------------------- width-12: breaking the wall ----------------------

def test_width12_sampled_evolve_completes_on_cpu():
    """A width-12 multiplier evolve run completes under eval_mode="sampled"
    (exhaustive would need a 16.7M-row cube per candidate), with per-metric
    confidence intervals reported."""
    from repro.core.sweep import SweepConfig, run_sweep_batched
    gold, spec = G.array_multiplier(12, n_n=None)  # auto-sized netlist
    cfg = SearchConfig(
        width=12, kind="mul", n_n=spec.n_n,
        evolve=EvolveConfig(generations=3, lam=2, eval_mode="sampled",
                            sample_size=2048, input_dist="uniform"))
    res = run_sweep_batched(cfg, [ConstraintSpec(mae=2.0)], (0,),
                            SweepConfig(chunk_size=1, keep_history="none"))
    assert res.completed == 1
    rec = res.records[0]
    assert rec.metrics.shape == (M.N_METRICS,)
    assert np.isfinite(rec.metrics).all()
    assert rec.metrics_stderr.shape == (M.N_METRICS,)
    assert np.isfinite(rec.metrics_stderr).all()

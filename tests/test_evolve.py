"""Evolution loop: fitness semantics, selection monotonicity, search gains."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig, evolve
from repro.core.fitness import ConstraintSpec, feasible, fitness
from repro.core.search import SearchConfig, problem_arrays, run_search


def test_fitness_infeasible_is_inf():
    thr = jnp.asarray(ConstraintSpec(mae=1.0).thresholds())
    bad = jnp.zeros((M.N_METRICS,)).at[M.MAE].set(2.0)
    good = jnp.zeros((M.N_METRICS,)).at[M.MAE].set(0.5)
    assert np.isinf(float(fitness(jnp.float32(10.0), bad, thr)))
    assert float(fitness(jnp.float32(10.0), good, thr)) == 10.0


def test_boolean_constraints_lower_bounded():
    thr = jnp.asarray(ConstraintSpec(acc0=True).thresholds())
    v = jnp.zeros((M.N_METRICS,))
    assert not bool(feasible(v, thr))            # acc0 = 0 -> infeasible
    assert bool(feasible(v.at[M.ACC0].set(1.0), thr))


def _run(width=3, gens=400, lam=6, con=None, seed=0, n_n=100):
    cfg = SearchConfig(width=width, n_n=n_n,
                       evolve=EvolveConfig(generations=gens, lam=lam))
    gold, spec, planes, gvals, gpower = problem_arrays(cfg)
    con = con or ConstraintSpec(mae=2.0)
    thr = jnp.asarray(con.thresholds())
    res = evolve(spec, cfg.evolve, gold, thr, planes, gvals, gpower,
                 jax.random.PRNGKey(seed))
    return res, gpower


def test_parent_fitness_monotone_nonincreasing():
    res, _ = _run()
    fit = np.asarray(res.hist_fit)
    fit = np.where(np.isinf(fit), np.nan, fit)
    diffs = np.diff(fit[np.isfinite(fit)])
    assert (diffs <= 1e-5).all()


def test_evolution_reduces_power_under_loose_constraint():
    res, gpower = _run(gens=800, con=ConstraintSpec(mae=5.0), seed=1)
    assert float(res.hist_power_rel[-1]) < 0.98, (
        "no power reduction found in 800 generations")


def test_final_circuit_respects_constraints():
    con = ConstraintSpec(mae=2.0, er=80.0)
    cfg = SearchConfig(width=3, n_n=100,
                       evolve=EvolveConfig(generations=400, lam=6))
    rec, res = run_search(cfg, con, seed=0)
    assert rec.feasible
    assert rec.metrics[M.MAE] <= 2.0 + 1e-4
    assert rec.metrics[M.ER] <= 80.0 + 1e-4


def test_acc0_constraint_is_maintained():
    con = ConstraintSpec(mae=5.0, acc0=True)
    cfg = SearchConfig(width=3, n_n=100,
                       evolve=EvolveConfig(generations=300, lam=6))
    rec, _ = run_search(cfg, con, seed=2)
    assert rec.feasible and rec.metrics[M.ACC0] == 1.0


@pytest.mark.kernel_diff
def test_pallas_backend_matches_jnp_backend():
    cfg = SearchConfig(width=3, n_n=80,
                       evolve=EvolveConfig(generations=60, lam=3,
                                           backend="pallas"))
    gold, spec, planes, gvals, gpower = problem_arrays(cfg)
    thr = jnp.asarray(ConstraintSpec(mae=2.0).thresholds())
    r1 = evolve(spec, cfg.evolve, gold, thr, planes, gvals, gpower,
                jax.random.PRNGKey(0))
    ecfg2 = dataclasses.replace(cfg.evolve, backend="jnp")
    r2 = evolve(spec, ecfg2, gold, thr, planes, gvals, gpower,
                jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(r1.hist_fit),
                               np.asarray(r2.hist_fit), rtol=1e-5)


def test_library_roundtrip(tmp_path):
    from repro.core import library as L
    cfg = SearchConfig(width=3, n_n=80,
                       evolve=EvolveConfig(generations=100, lam=4))
    rec, _ = run_search(cfg, ConstraintSpec(mae=3.0), seed=0)
    path = str(tmp_path / "lib.json")
    L.save_library([rec], path)
    lib = L.load_library(path)
    assert len(lib) == 1
    best = L.select_best(lib, mae=3.0)
    assert best is not None
    g = L.record_to_genome(best)
    assert g.nodes.shape == (80, 3)
    lut = L.multiplier_lut(g, __import__(
        "repro.core.genome", fromlist=["CGPSpec"]).CGPSpec(6, 6, 80))
    assert lut.shape == (8, 8)


def test_pareto_front():
    from repro.core.pareto import pareto_front, pareto_points, hypervolume_2d
    pts = np.array([[1, 5], [2, 3], [3, 4], [4, 1], [5, 5], [2.5, 3]])
    m = pareto_front(pts)
    assert set(map(tuple, pts[m])) == {(1, 5), (2, 3), (4, 1)}
    hv = hypervolume_2d(pts, (6, 6))
    assert hv > 0
    # adding a dominated point must not change the hypervolume
    hv2 = hypervolume_2d(np.vstack([pts, [5, 5.5]]), (6, 6))
    assert abs(hv - hv2) < 1e-9

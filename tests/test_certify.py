"""Differential certification harness for the exact-verification tier
(DESIGN.md §10, ``core.certify``).

The contracts this file proves:

  * certified metrics are BIT-IDENTICAL to the exhaustive oracle
    (``metrics.metrics_np`` over ``simulate.simulate_values_np``) at widths
    where the oracle is tractable — for standalone ``certified_metrics``
    calls AND for every elite the sweep's escalation driver certifies;
  * the chunked bit-parallel regime agrees with the full-cube dispatch
    exactly on the integer-derived metrics (MAE/WCE/ER/AVG/ACC0/GAUSS) and
    to float64-reassociation tolerance on MRE;
  * certified WCE is an upper bound of every sampled lower bound, and a
    sampled ACC0 failure is never contradicted by the certified verdict;
  * sampled hard constraints (WCE/ACC0/GAUSS) are *uncertified* without an
    escalation: ``metric_stderr`` reports 0 for them (no CLT interval to
    lean on) and sampled-feasible rows keep ``certified=False`` unless the
    exact tier re-measured them.

Heavy legs (width ≥ 8 oracles, a width-12 escalation) carry the
``certify`` marker: excluded from the default tier-1 run, included in
``make test-full`` and the CI certify leg.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import certify
from repro.core import golden as G
from repro.core import metrics as M
from repro.core import sampling, simulate
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec, feasible
from repro.core.genome import Genome
from repro.core.mutate import mutate_population
from repro.core.search import SearchConfig
from repro.core.sweep import (SweepConfig, grid_fingerprint,
                              run_sweep_batched, sweep_grid)

SIGMA = 256.0


def _mutants(width, n_n, count, rate=0.05, seed=0):
    """(spec, nodes (count, n_n, 3), outs (count, n_o)) — mutated copies of
    the exact golden netlist, so error metrics are small but nonzero."""
    gold, spec = G.array_multiplier(width, n_n=n_n)
    pop = mutate_population(jax.random.PRNGKey(seed), gold, spec, count, rate)
    return spec, np.asarray(pop.nodes), np.asarray(pop.outs)


def _oracle(nodes, outs, spec, width, kind="mul", sigma=SIGMA):
    """The exhaustive-tier oracle: NumPy reference simulation of the full
    cube finalized through ``metrics_np`` — independent of the jit'd
    simulation path ``certified_metrics`` dispatches."""
    n = 1 << spec.n_i
    cvals = simulate.simulate_values_np(
        Genome(np.asarray(nodes), np.asarray(outs)), spec)[:n]
    gvals = G.golden_values(width, kind)[:n]
    return M.metrics_np(gvals, cvals, spec.n_o, sigma)


def _sampled_metrics(nodes, outs, spec, width, sample_size, sample_seed,
                     kind="mul", sigma=SIGMA):
    """Sampled-tier metrics of one genome: the same packed sample planes the
    sampled kernel consumes (§9 operand streams), finalized through
    ``metrics_np``."""
    planes, gvals = sampling.sample_problem(width, kind, sample_size,
                                            "uniform", sample_seed)
    cvals = np.asarray(simulate.simulate_values(
        Genome(jnp.asarray(nodes), jnp.asarray(outs)), spec,
        jnp.asarray(planes)))
    return M.metrics_np(gvals.astype(np.int64), cvals, spec.n_o, sigma)


# ---------------------- certified_metrics vs the oracle --------------------

@pytest.mark.parametrize("width,n_n", [(3, 64), (4, 80), (5, 120)])
def test_certified_metrics_bit_identical_to_oracle(width, n_n):
    """Full-cube dispatch regime: certified == exhaustive oracle, bitwise,
    including for genomes with nonzero error."""
    spec, nodes, outs = _mutants(width, n_n, 4)
    for i in range(len(nodes)):
        cert = certify.certified_metrics(nodes[i], outs[i], spec, "mul",
                                         width, SIGMA)
        np.testing.assert_array_equal(
            cert, _oracle(nodes[i], outs[i], spec, width))


def test_certified_metrics_add_kind():
    gold, spec = G.ripple_carry_adder(4, n_n=40)
    pop = mutate_population(jax.random.PRNGKey(3), gold, spec, 3, 0.05)
    nodes, outs = np.asarray(pop.nodes), np.asarray(pop.outs)
    for i in range(3):
        cert = certify.certified_metrics(nodes[i], outs[i], spec, "add",
                                         4, SIGMA)
        np.testing.assert_array_equal(
            cert, _oracle(nodes[i], outs[i], spec, 4, kind="add"))


def test_chunked_pass_matches_full_dispatch():
    """Forcing the chunked bit-parallel regime (tiny dispatch budget) must
    agree with the one-dispatch answer: exactly on every integer-derived
    metric, to float64-reassociation tolerance on MRE."""
    spec, nodes, outs = _mutants(5, 120, 4)
    int_exact = [M.MAE, M.WCE, M.ER, M.AVG, M.ACC0, M.GAUSS]
    for i in range(len(nodes)):
        full = certify.certified_metrics(nodes[i], outs[i], spec, "mul",
                                         5, SIGMA)
        chunked = certify.certified_metrics(nodes[i], outs[i], spec, "mul",
                                            5, SIGMA, dispatch_rows=128)
        np.testing.assert_array_equal(chunked[int_exact], full[int_exact])
        np.testing.assert_allclose(chunked[M.MRE], full[M.MRE], rtol=1e-6)
        # and the full dispatch is the oracle, so the chunked integer
        # metrics are transitively exact
        np.testing.assert_array_equal(
            full, _oracle(nodes[i], outs[i], spec, 5))


def test_cube_slice_planes_match_exhaustive_packing():
    full = simulate.input_planes_np(10)  # width-5 cube: 1024 rows, 32 words
    np.testing.assert_array_equal(certify.cube_slice_planes(10, 0, 1024),
                                  full)
    # a mid-cube slice is the corresponding word columns of the full cube
    np.testing.assert_array_equal(certify.cube_slice_planes(10, 512, 512),
                                  full[:, 16:])
    with pytest.raises(ValueError):
        certify.cube_slice_planes(4, 0, 31)


# ----------------- sampled lower bounds vs certified truth -----------------

def test_certified_wce_dominates_sampled_lower_bound():
    """Property (over genomes × sample streams): the sample max is a lower
    bound, so certified WCE >= sampled WCE always — and a sampled ACC0
    failure (observed violation) is never contradicted by the certified
    verdict."""
    spec, nodes, outs = _mutants(4, 80, 6, rate=0.08)
    saw_strict = False
    for i in range(len(nodes)):
        cert = certify.certified_metrics(nodes[i], outs[i], spec, "mul",
                                         4, SIGMA)
        for sample_seed in range(3):
            samp = _sampled_metrics(nodes[i], outs[i], spec, 4, 64,
                                    sample_seed)
            assert cert[M.WCE] >= samp[M.WCE]
            saw_strict |= bool(cert[M.WCE] > samp[M.WCE])
            if samp[M.ACC0] == 0.0:  # violation observed on the sample
                assert cert[M.ACC0] == 0.0
    assert saw_strict, "every sample saw the true WCE — property is vacuous"


# -------------------- stderr misuse guard (satellite 2) --------------------

def test_metric_stderr_zero_for_uncertifiable_metrics():
    """Regression guard: WCE/ACC0/GAUSS have no CLT interval — a sample max
    / indicator verdict admits no standard error, and downstream code must
    never read a confidence bound for them."""
    rng = np.random.default_rng(0)
    g = rng.integers(0, 256, 2048).astype(np.int64)
    c = np.clip(g + rng.integers(-5, 6, 2048), 0, 255).astype(np.int64)
    partials = M.error_partials(jnp.asarray(g, jnp.int32),
                                jnp.asarray(c, jnp.int32), SIGMA, n_bits=8)
    sterr = np.asarray(M.metric_stderr(partials, 8))
    assert (sterr[list(certify.UNCERTIFIABLE)] == 0).all()
    assert sterr[M.MAE] > 0  # CLT metrics do report an interval


def test_requires_certification_flags_hard_constraints():
    assert certify.requires_certification(ConstraintSpec(wce=2.0).thresholds())
    assert certify.requires_certification(
        ConstraintSpec(acc0=True).thresholds())
    assert certify.requires_certification(
        ConstraintSpec(gauss=True, gauss_sigma=SIGMA).thresholds())
    # CLT-bounded metrics alone do not demand the exact tier
    assert not certify.requires_certification(
        ConstraintSpec(mae=0.5, er=60.0, mre=5.0, avg=1.0).thresholds())


def test_sampled_hard_constraint_stays_uncertified_without_escalation():
    """The guard itself: a sampled sweep whose constraint binds WCE can be
    feasible ON THE SAMPLE, but no row is certified unless the escalation
    tier ran."""
    cfg = SearchConfig(
        width=3, kind="mul", n_n=64,
        evolve=EvolveConfig(generations=20, lam=3, eval_mode="sampled",
                            sample_size=48))
    res = run_sweep_batched(cfg, [ConstraintSpec(wce=30.0)], (0, 1),
                            SweepConfig(chunk_size=2, keep_history="none"))
    assert certify.requires_certification(ConstraintSpec(wce=30.0)
                                          .thresholds())
    assert res.feasible.any()            # satisfied on the sample...
    assert not res.certified_mask.any()  # ...but nothing is certified
    assert all(not r.certified for r in res.records)
    assert res.certify_stats is None


def test_exhaustive_rows_certified_by_census():
    """An exhaustive sweep is its own certificate: every row certified, no
    escalations, and the certify flag is fingerprint-neutral there."""
    cfg = SearchConfig(width=3, kind="mul", n_n=64,
                       evolve=EvolveConfig(generations=15, lam=3))
    res = run_sweep_batched(cfg, [ConstraintSpec(mae=8.0)], (0,),
                            SweepConfig(chunk_size=2, keep_history="none"))
    assert res.certified_mask.all()
    assert all(r.certified for r in res.records)
    assert res.certify_stats is None  # no escalation tier ran


# ------------------- the sweep escalation driver (§10) ---------------------

def _sweep_cfg(certify_on, budget=8, width=4, n_n=80):
    return SearchConfig(
        width=width, kind="mul", n_n=n_n,
        evolve=EvolveConfig(generations=40, lam=3, eval_mode="sampled",
                            sample_size=128, certify=certify_on,
                            certify_budget=budget))


def test_sweep_escalated_elites_bit_identical_to_oracle():
    """The differential harness proper: every elite the driver certifies
    carries metrics bit-identical to the exhaustive oracle recomputed from
    its genome, with zeroed stderr and an exact-feasibility verdict."""
    cfg = _sweep_cfg(True)
    cons = [ConstraintSpec(wce=25.0, acc0=True), ConstraintSpec(mae=8.0)]
    res = run_sweep_batched(cfg, cons, (0, 1),
                            SweepConfig(chunk_size=2, keep_history="none"))
    _, spec = G.array_multiplier(4, n_n=80)
    certified = np.flatnonzero(res.certified_mask)
    assert certified.size, "no elite escalated — the harness is vacuous"
    assert res.certify_stats["escalated"] == certified.size
    assert res.completed == res.n_runs  # records are grid-ordered and full
    for i in certified:
        r = res.records[i]
        assert r.certified
        oracle = _oracle(r.genome_nodes, r.genome_outs, spec, 4)
        np.testing.assert_array_equal(r.metrics, oracle)
        np.testing.assert_array_equal(res.metrics[i], oracle)
        assert (r.metrics_stderr == 0).all()
        # the shipped feasibility verdict is the EXACT one (Eq. 9 on the
        # certified metrics, against this row's own thresholds)
        assert bool(res.feasible[i]) == certify.feasible_np(
            oracle, res.thresholds[i])


def test_escalation_respects_budget():
    cfg = _sweep_cfg(True, budget=1)
    cons = [ConstraintSpec(wce=25.0), ConstraintSpec(mae=8.0)]
    res = run_sweep_batched(cfg, cons, (0, 1),
                            SweepConfig(chunk_size=2, keep_history="none"))
    # 2 chunks, base budget 1, ramp=1 → caps 1 and 2: at most 3 escalations
    assert 1 <= res.certify_stats["escalated"] <= 3
    # certified rows are the sampled-feasible ones with the LOWEST power
    # among their chunk's eligibles — at minimum, all certified rows were
    # sampled-feasible at escalation time
    assert res.certified_mask.sum() == res.certify_stats["certified_rows"]


def test_certify_policy_budget_ramp():
    pol = certify.CertifyPolicy(budget=4, ramp=1.0)
    caps = [pol.chunk_budget(i, 10) for i in range(10)]
    assert caps[0] == 4 and caps[-1] == 8
    assert all(a <= b for a, b in zip(caps, caps[1:]))  # monotone ramp
    assert certify.CertifyPolicy(budget=4, ramp=0.0).chunk_budget(9, 10) == 4
    assert certify.CertifyPolicy(budget=4).chunk_budget(0, 1) == 4
    with pytest.raises(ValueError):
        certify.CertifyPolicy(budget=0)
    with pytest.raises(ValueError):
        certify.CertifyPolicy(ramp=-0.1)
    with pytest.raises(ValueError):
        certify.CertifyPolicy(dispatch_rows=33)
    with pytest.raises(ValueError):
        EvolveConfig(certify_budget=0)


def test_select_escalations_orders_by_power_and_skips_certified():
    feas = np.array([1, 0, 1, 1, 1], bool)
    power = np.array([0.9, 0.1, 0.3, 0.5, 0.2], np.float32)
    done = np.array([0, 0, 0, 1, 0], bool)
    # eligible: rows 0, 2, 4 (1 infeasible, 3 already certified), best first
    np.testing.assert_array_equal(
        certify.select_escalations(feas, power, done, 10), [4, 2, 0])
    np.testing.assert_array_equal(
        certify.select_escalations(feas, power, done, 2), [4, 2])
    assert certify.select_escalations(feas, power, done, 0).size == 0


def test_feasible_np_mirrors_jax_predicate():
    rng = np.random.default_rng(1)
    specs = [ConstraintSpec(mae=1.0, wce=1.5),
             ConstraintSpec(wce=0.5, acc0=True),
             ConstraintSpec(er=50.0, gauss=True, gauss_sigma=SIGMA),
             ConstraintSpec(mae=0.2)]
    for _ in range(16):
        m = rng.uniform(0, 2, M.N_METRICS).astype(np.float32)
        m[M.ACC0] = float(rng.integers(0, 2))
        m[M.GAUSS] = float(rng.integers(0, 2))
        for con in specs:
            t = con.thresholds()
            assert certify.feasible_np(m, t) == bool(np.asarray(
                feasible(jnp.asarray(m), jnp.asarray(t))))


def test_certify_joins_sampled_grid_fingerprint_only_when_on():
    grid = sweep_grid([ConstraintSpec(mae=1.0)], (0,))

    def fp(eval_mode, certify_on, budget=8):
        cfg = SearchConfig(
            width=3, kind="mul", n_n=64,
            evolve=EvolveConfig(eval_mode=eval_mode, sample_size=64,
                                certify=certify_on, certify_budget=budget))
        return grid_fingerprint(cfg, grid, "none")

    # exhaustive fingerprints ignore the certify knobs entirely
    assert fp("exhaustive", False) == fp("exhaustive", True)
    # sampled: off == pre-§10 identity; on keys the directory apart,
    # budget changes the identity too (it changes which rows get exact)
    assert fp("sampled", False) != fp("sampled", True)
    assert fp("sampled", True, 8) != fp("sampled", True, 4)


# ------------------------- heavy parity legs -------------------------------

@pytest.mark.certify
def test_width8_certified_bit_identity():
    """Acceptance leg: width-8 mutated elites, certified vs the 65536-row
    exhaustive oracle, bitwise."""
    spec, nodes, outs = _mutants(8, None, 4, rate=0.02)
    for i in range(len(nodes)):
        cert = certify.certified_metrics(nodes[i], outs[i], spec, "mul",
                                         8, SIGMA)
        np.testing.assert_array_equal(
            cert, _oracle(nodes[i], outs[i], spec, 8))


@pytest.mark.certify
def test_width8_chunked_regime_exact():
    """The chunked bit-parallel pass at width 8 (8 dispatches) against the
    oracle: integer metrics exact."""
    spec, nodes, outs = _mutants(8, None, 2, rate=0.02)
    int_exact = [M.MAE, M.WCE, M.ER, M.AVG, M.ACC0, M.GAUSS]
    for i in range(len(nodes)):
        chunked = certify.certified_metrics(nodes[i], outs[i], spec, "mul",
                                            8, SIGMA, dispatch_rows=8192)
        oracle = _oracle(nodes[i], outs[i], spec, 8)
        np.testing.assert_array_equal(chunked[int_exact], oracle[int_exact])
        np.testing.assert_allclose(chunked[M.MRE], oracle[M.MRE], rtol=1e-6)


@pytest.mark.certify
def test_width12_sampled_sweep_certify_emits_exact_elites():
    """The acceptance scenario: a width-12 sampled sweep under --certify
    escalates its elite through the chunked exact pass (16.7M-row cube, 16
    dispatches) and emits certified metrics with zero stderr."""
    gold, spec = G.array_multiplier(12, n_n=None)  # auto-sized netlist
    cfg = SearchConfig(
        width=12, kind="mul", n_n=spec.n_n,
        evolve=EvolveConfig(generations=3, lam=2, eval_mode="sampled",
                            sample_size=2048, certify=True,
                            certify_budget=1))
    res = run_sweep_batched(cfg, [ConstraintSpec(wce=25.0)], (0,),
                            SweepConfig(chunk_size=1, keep_history="none"))
    assert res.certify_stats["escalated"] == 1
    rec = res.records[0]
    assert rec.certified
    assert (rec.metrics_stderr == 0).all()
    assert np.isfinite(rec.metrics).all()
    # certified WCE must dominate the sampled lower bound of the same genome
    samp = _sampled_metrics(rec.genome_nodes, rec.genome_outs, spec, 12,
                            2048, 0)
    assert rec.metrics[M.WCE] >= samp[M.WCE]

"""Streaming results layer: spill/read-back round trips vs the in-RAM path.

The shard reader must be BIT-identical to the in-RAM ``SweepResult`` on the
same grid (``metric_correlations``/``sweep_fronts``/records), in every
history mode; an interrupted sweep must replay into a consistent shard set
(each grid row exactly once); and foreign/incompatible shard directories
must be refused, mirroring the checkpoint fingerprint guards.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import certify
from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.results import (SCHEMA_VERSION, SweepResultReader,
                                SweepResultWriter, normalize_history_mode)
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=50, lam=3))
CONSTRAINTS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
               ConstraintSpec(er=50.0)]
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)


@pytest.fixture(scope="module")
def in_ram():
    """The in-RAM oracle: full histories, no spill."""
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                             SweepConfig(chunk_size=4, keep_history="full"))


def _spill(tmp_path, mode, chunk_size=4, **kw):
    sweep = SweepConfig(chunk_size=chunk_size, keep_history=mode,
                        results_dir=str(tmp_path), **kw)
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS, sweep)


def _assert_reader_matches(reader, in_ram):
    s = reader.summary()
    assert s["done_mask"].all() and reader.completed == N_RUNS
    np.testing.assert_array_equal(s["metrics"], in_ram.metrics)
    np.testing.assert_array_equal(s["power_rel"], in_ram.power_rel)
    np.testing.assert_array_equal(s["feasible"].astype(bool),
                                  in_ram.feasible)
    np.testing.assert_array_equal(s["best_fit"], in_ram.best_fit)
    np.testing.assert_array_equal(s["thresholds"], in_ram.thresholds)
    # exhaustive grid: every row census-certified, round-tripped (§10)
    np.testing.assert_array_equal(s["certified_mask"].astype(bool),
                                  in_ram.certified_mask)
    # the paper's analyses, bit-for-bit (ISSUE 3 acceptance)
    np.testing.assert_array_equal(reader.correlations(),
                                  in_ram.correlations())
    want, got = in_ram.fronts((M.MAE, M.ER)), reader.fronts((M.MAE, M.ER))
    assert want.keys() == got.keys()
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])


@pytest.mark.parametrize("mode", ["none", "summary", "full"])
def test_spill_round_trip_matches_in_ram(tmp_path, in_ram, mode):
    res = _spill(tmp_path, mode)
    _assert_reader_matches(res.reader(), in_ram)
    # history placement follows the mode
    if mode == "full":
        np.testing.assert_array_equal(res.hist_fit, in_ram.hist_fit)
    else:
        assert res.hist_fit is None and res.hist_metrics is None
    reader = res.reader()
    assert reader.keep_history == mode
    if mode == "none":
        with pytest.raises(ValueError, match="no per-generation"):
            next(reader.iter_history())
    else:
        hist = np.zeros_like(in_ram.hist_fit)
        seen = 0
        for rows, h in reader.iter_history():
            hist[rows] = h["hist_fit"]
            seen += len(rows)
            assert h["hist_metrics"].shape[1:] == (50, M.N_METRICS)
        assert seen == N_RUNS
        np.testing.assert_array_equal(hist, in_ram.hist_fit)


def test_reader_records_match_in_ram(tmp_path, in_ram):
    recs = _spill(tmp_path, "summary").reader().records()
    assert len(recs) == len(in_ram.records)
    for a, b in zip(recs, in_ram.records):
        assert (a.constraint, a.seed, a.feasible) == \
            (b.constraint, b.seed, b.feasible)
        np.testing.assert_array_equal(a.metrics, b.metrics)
        np.testing.assert_array_equal(a.genome_nodes, b.genome_nodes)
        np.testing.assert_array_equal(a.genome_outs, b.genome_outs)
        assert a.power_rel == b.power_rel


def test_interrupted_sweep_replays_into_consistent_shards(tmp_path, in_ram):
    """Mid-grid interruption + resume: no duplicated or dropped runs, and
    the resumed shard set reproduces the uninterrupted results exactly."""
    partial = _spill(tmp_path, "full", max_chunks=1)
    assert partial.completed == 4
    assert partial.reader().completed == 4
    resumed = _spill(tmp_path, "full")
    reader = resumed.reader()
    rows = np.concatenate([s["grid_rows"] for _, s
                           in reader.iter_shards(fields=("grid_rows",))])
    assert sorted(rows.tolist()) == list(range(N_RUNS))  # exactly once each
    _assert_reader_matches(reader, in_ram)
    # full-mode resume restores histories from the shard set, not recompute
    np.testing.assert_array_equal(resumed.hist_fit, in_ram.hist_fit)
    # a third run finds nothing to do
    again = _spill(tmp_path, "full")
    assert again.runs_per_sec == 0.0 and again.completed == N_RUNS
    np.testing.assert_array_equal(again.metrics, in_ram.metrics)


def test_results_dir_resume_with_checkpoints_too(tmp_path, in_ram):
    """checkpoint_dir and results_dir together: shards drive the resume,
    checkpoints keep committing."""
    ck = str(tmp_path / "ck")
    rd = tmp_path / "shards"
    partial = _spill(rd, "summary", checkpoint_dir=ck, max_chunks=1)
    assert partial.completed == 4 and os.path.isdir(ck)
    resumed = _spill(rd, "summary", checkpoint_dir=ck)
    _assert_reader_matches(resumed.reader(), in_ram)


def test_foreign_grid_and_chunk_size_refused(tmp_path):
    _spill(tmp_path, "summary", max_chunks=1)
    with pytest.raises(ValueError, match="different sweep"):
        run_sweep_batched(CFG, CONSTRAINTS[:2], SEEDS,
                          SweepConfig(chunk_size=4, keep_history="summary",
                                      results_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="different sweep"):
        _spill(tmp_path, "summary", chunk_size=3)
    with pytest.raises(ValueError, match="different sweep"):
        _spill(tmp_path, "full")  # history mode changes the shard schema


def test_writer_reset_wipes_foreign_shards(tmp_path):
    _spill(tmp_path, "summary", max_chunks=1)
    writer = SweepResultWriter(
        str(tmp_path), grid_fingerprint="other", grid_meta=[], n_runs=2,
        gens=8, n_n=10, n_o=4, keep_history="none", chunk_size=2,
        on_mismatch="reset")
    assert writer.spans() == [] and writer.coverage() == 0


def test_history_mode_normalization():
    assert normalize_history_mode(True) == "full"
    assert normalize_history_mode(False) == "none"
    assert SweepConfig(keep_history=True).keep_history == "full"
    assert SweepConfig(keep_history=False).keep_history == "none"
    assert SweepConfig(keep_history="summary").keep_history == "summary"
    with pytest.raises(ValueError, match="keep_history"):
        SweepConfig(keep_history="everything")


def test_reader_requires_manifest(tmp_path):
    with pytest.raises(FileNotFoundError):
        SweepResultReader(str(tmp_path))
    res = run_sweep_batched(CFG, CONSTRAINTS[:1], (0,),
                            SweepConfig(chunk_size=1, keep_history="none"))
    with pytest.raises(ValueError, match="results_dir"):
        res.reader()


# ----------- schema v3: the certified_mask column (DESIGN.md §10) ----------

# budget 1 on 3 chunks of 2 (ramp caps 1/2/2) cannot cover all 6 feasible
# rows, so the shard set holds BOTH certified and uncertified rows
_SAMPLED_CFG = SearchConfig(
    width=3, kind="mul", n_n=64,
    evolve=EvolveConfig(generations=25, lam=3, eval_mode="sampled",
                        sample_size=48, certify=True, certify_budget=1))
_SAMPLED_CONS = [ConstraintSpec(wce=30.0), ConstraintSpec(mae=8.0),
                 ConstraintSpec(er=80.0)]


def _sampled_spill(tmp_path, **kw):
    sweep = SweepConfig(chunk_size=2, keep_history="none",
                        results_dir=str(tmp_path), **kw)
    return run_sweep_batched(_SAMPLED_CFG, _SAMPLED_CONS, SEEDS, sweep)


def test_certified_mask_round_trips_schema_v3(tmp_path):
    res = _sampled_spill(tmp_path)
    assert res.certified_mask.any(), "no escalations — round trip is vacuous"
    assert not res.certified_mask.all(), "budget failed to leave a mix"
    reader = res.reader()
    assert reader.schema_version == SCHEMA_VERSION == 3
    s = reader.summary(["certified_mask", "metrics_stderr", "metrics"])
    np.testing.assert_array_equal(s["certified_mask"].astype(bool),
                                  res.certified_mask)
    np.testing.assert_array_equal(s["metrics"], res.metrics)
    # certified rows spill with zero stderr (exact measurements)
    assert (s["metrics_stderr"][res.certified_mask] == 0).all()
    recs = reader.records()
    assert [r.certified for r in recs] == res.certified_mask.tolist()


def test_escalations_ride_resume_without_recertifying(tmp_path, monkeypatch):
    """Satellite: certified results are part of the shard resume state — an
    interrupted sweep never re-runs the exact tier for rows a committed
    chunk already certified."""
    partial = _sampled_spill(tmp_path, max_chunks=2)
    done1 = partial.certified_mask.copy()
    assert done1.any(), "interrupt landed before any escalation"

    calls = []
    real = certify.certified_metrics

    def counting(*args, **kw):
        calls.append(args)
        return real(*args, **kw)

    monkeypatch.setattr(certify, "certified_metrics", counting)
    resumed = _sampled_spill(tmp_path)
    assert resumed.completed == N_RUNS
    # previously-certified rows ride the restored shards untouched...
    assert resumed.certified_mask[done1].all()
    # ...and the exact tier ran only for the chunks executed this call
    assert len(calls) == int(resumed.certified_mask.sum() - done1.sum())

    calls.clear()
    again = _sampled_spill(tmp_path)  # fully-covered directory: no-op
    assert again.completed == N_RUNS and not calls
    np.testing.assert_array_equal(again.certified_mask,
                                  resumed.certified_mask)


def _downgrade_to_v2(results_dir):
    """Rewrite a v3 directory as its v2 equivalent: drop the
    certified_mask column and stamp the old version."""
    man_path = os.path.join(str(results_dir), "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["schema_version"] = 2
    with open(man_path, "w") as f:
        json.dump(man, f)
    for name in os.listdir(str(results_dir)):
        if not name.startswith("shard_"):
            continue
        p = os.path.join(str(results_dir), name)
        with np.load(p) as z:
            data = {k: z[k] for k in z.files if k != "certified_mask"}
        np.savez(p, **data)


def test_v2_directories_read_with_certified_default(tmp_path, in_ram):
    """Backward-readability: v2 shard sets (pre-§10) load fine, with
    certified_mask defaulting to 0 for every row."""
    _spill(tmp_path, "summary")
    _downgrade_to_v2(tmp_path)
    reader = SweepResultReader(str(tmp_path))
    assert reader.schema_version == 2
    s = reader.summary()
    assert s["done_mask"].all()
    assert not s["certified_mask"].any()  # reader-side default
    np.testing.assert_array_equal(s["metrics"], in_ram.metrics)
    assert all(not r.certified for r in reader.records())
    # full-field shard iteration also works without the absent column
    for _, rows in reader.iter_shards():
        assert "certified_mask" not in rows and "metrics" in rows


def test_future_schema_version_refused(tmp_path):
    _spill(tmp_path, "none", max_chunks=1)
    man_path = os.path.join(str(tmp_path), "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["schema_version"] = SCHEMA_VERSION + 1
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="not readable"):
        SweepResultReader(str(tmp_path))

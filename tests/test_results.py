"""Streaming results layer: spill/read-back round trips vs the in-RAM path.

The shard reader must be BIT-identical to the in-RAM ``SweepResult`` on the
same grid (``metric_correlations``/``sweep_fronts``/records), in every
history mode; an interrupted sweep must replay into a consistent shard set
(each grid row exactly once); and foreign/incompatible shard directories
must be refused, mirroring the checkpoint fingerprint guards.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.results import (SweepResultReader, SweepResultWriter,
                                normalize_history_mode)
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=50, lam=3))
CONSTRAINTS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
               ConstraintSpec(er=50.0)]
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)


@pytest.fixture(scope="module")
def in_ram():
    """The in-RAM oracle: full histories, no spill."""
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                             SweepConfig(chunk_size=4, keep_history="full"))


def _spill(tmp_path, mode, chunk_size=4, **kw):
    sweep = SweepConfig(chunk_size=chunk_size, keep_history=mode,
                        results_dir=str(tmp_path), **kw)
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS, sweep)


def _assert_reader_matches(reader, in_ram):
    s = reader.summary()
    assert s["done_mask"].all() and reader.completed == N_RUNS
    np.testing.assert_array_equal(s["metrics"], in_ram.metrics)
    np.testing.assert_array_equal(s["power_rel"], in_ram.power_rel)
    np.testing.assert_array_equal(s["feasible"].astype(bool),
                                  in_ram.feasible)
    np.testing.assert_array_equal(s["best_fit"], in_ram.best_fit)
    np.testing.assert_array_equal(s["thresholds"], in_ram.thresholds)
    # the paper's analyses, bit-for-bit (ISSUE 3 acceptance)
    np.testing.assert_array_equal(reader.correlations(),
                                  in_ram.correlations())
    want, got = in_ram.fronts((M.MAE, M.ER)), reader.fronts((M.MAE, M.ER))
    assert want.keys() == got.keys()
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])


@pytest.mark.parametrize("mode", ["none", "summary", "full"])
def test_spill_round_trip_matches_in_ram(tmp_path, in_ram, mode):
    res = _spill(tmp_path, mode)
    _assert_reader_matches(res.reader(), in_ram)
    # history placement follows the mode
    if mode == "full":
        np.testing.assert_array_equal(res.hist_fit, in_ram.hist_fit)
    else:
        assert res.hist_fit is None and res.hist_metrics is None
    reader = res.reader()
    assert reader.keep_history == mode
    if mode == "none":
        with pytest.raises(ValueError, match="no per-generation"):
            next(reader.iter_history())
    else:
        hist = np.zeros_like(in_ram.hist_fit)
        seen = 0
        for rows, h in reader.iter_history():
            hist[rows] = h["hist_fit"]
            seen += len(rows)
            assert h["hist_metrics"].shape[1:] == (50, M.N_METRICS)
        assert seen == N_RUNS
        np.testing.assert_array_equal(hist, in_ram.hist_fit)


def test_reader_records_match_in_ram(tmp_path, in_ram):
    recs = _spill(tmp_path, "summary").reader().records()
    assert len(recs) == len(in_ram.records)
    for a, b in zip(recs, in_ram.records):
        assert (a.constraint, a.seed, a.feasible) == \
            (b.constraint, b.seed, b.feasible)
        np.testing.assert_array_equal(a.metrics, b.metrics)
        np.testing.assert_array_equal(a.genome_nodes, b.genome_nodes)
        np.testing.assert_array_equal(a.genome_outs, b.genome_outs)
        assert a.power_rel == b.power_rel


def test_interrupted_sweep_replays_into_consistent_shards(tmp_path, in_ram):
    """Mid-grid interruption + resume: no duplicated or dropped runs, and
    the resumed shard set reproduces the uninterrupted results exactly."""
    partial = _spill(tmp_path, "full", max_chunks=1)
    assert partial.completed == 4
    assert partial.reader().completed == 4
    resumed = _spill(tmp_path, "full")
    reader = resumed.reader()
    rows = np.concatenate([s["grid_rows"] for _, s
                           in reader.iter_shards(fields=("grid_rows",))])
    assert sorted(rows.tolist()) == list(range(N_RUNS))  # exactly once each
    _assert_reader_matches(reader, in_ram)
    # full-mode resume restores histories from the shard set, not recompute
    np.testing.assert_array_equal(resumed.hist_fit, in_ram.hist_fit)
    # a third run finds nothing to do
    again = _spill(tmp_path, "full")
    assert again.runs_per_sec == 0.0 and again.completed == N_RUNS
    np.testing.assert_array_equal(again.metrics, in_ram.metrics)


def test_results_dir_resume_with_checkpoints_too(tmp_path, in_ram):
    """checkpoint_dir and results_dir together: shards drive the resume,
    checkpoints keep committing."""
    ck = str(tmp_path / "ck")
    rd = tmp_path / "shards"
    partial = _spill(rd, "summary", checkpoint_dir=ck, max_chunks=1)
    assert partial.completed == 4 and os.path.isdir(ck)
    resumed = _spill(rd, "summary", checkpoint_dir=ck)
    _assert_reader_matches(resumed.reader(), in_ram)


def test_foreign_grid_and_chunk_size_refused(tmp_path):
    _spill(tmp_path, "summary", max_chunks=1)
    with pytest.raises(ValueError, match="different sweep"):
        run_sweep_batched(CFG, CONSTRAINTS[:2], SEEDS,
                          SweepConfig(chunk_size=4, keep_history="summary",
                                      results_dir=str(tmp_path)))
    with pytest.raises(ValueError, match="different sweep"):
        _spill(tmp_path, "summary", chunk_size=3)
    with pytest.raises(ValueError, match="different sweep"):
        _spill(tmp_path, "full")  # history mode changes the shard schema


def test_writer_reset_wipes_foreign_shards(tmp_path):
    _spill(tmp_path, "summary", max_chunks=1)
    writer = SweepResultWriter(
        str(tmp_path), grid_fingerprint="other", grid_meta=[], n_runs=2,
        gens=8, n_n=10, n_o=4, keep_history="none", chunk_size=2,
        on_mismatch="reset")
    assert writer.spans() == [] and writer.coverage() == 0


def test_history_mode_normalization():
    assert normalize_history_mode(True) == "full"
    assert normalize_history_mode(False) == "none"
    assert SweepConfig(keep_history=True).keep_history == "full"
    assert SweepConfig(keep_history=False).keep_history == "none"
    assert SweepConfig(keep_history="summary").keep_history == "summary"
    with pytest.raises(ValueError, match="keep_history"):
        SweepConfig(keep_history="everything")


def test_reader_requires_manifest(tmp_path):
    with pytest.raises(FileNotFoundError):
        SweepResultReader(str(tmp_path))
    res = run_sweep_batched(CFG, CONSTRAINTS[:1], (0,),
                            SweepConfig(chunk_size=1, keep_history="none"))
    with pytest.raises(ValueError, match="results_dir"):
        res.reader()

"""Batched constraint-grid sweep engine vs the serial oracle.

The batched engine must reproduce the serial ``run_search`` loop per run
(same PRNG streams, same evaluation semantics — genomes match bit-for-bit on
CPU), stay invariant under chunking, and resume mid-grid from a checkpoint.
The same guarantees hold per backend: the fused-pallas sweep path is
bit-identical to the serial pallas loop, and (for constraints whose selection
depends only on exact integer partials) to the jnp backend.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.search import SearchConfig, run_sweep, run_sweep_serial
from repro.core.sweep import (SweepConfig, plan_chunks, run_sweep_batched,
                              sweep_grid)

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=80, lam=4))
CONSTRAINTS = ([ConstraintSpec(mae=t) for t in (0.5, 1.0, 2.0)]
               + [ConstraintSpec(er=e) for e in (25.0, 50.0)]
               + [ConstraintSpec(mae=1.0, er=50.0)])
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)


@pytest.fixture(scope="module")
def serial_records():
    return run_sweep_serial(CFG, CONSTRAINTS, SEEDS)


@pytest.fixture(scope="module")
def batched_result():
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                             SweepConfig(chunk_size=N_RUNS))


def _assert_records_match(a, b, exact_genomes=True):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.constraint == rb.constraint and ra.seed == rb.seed
        if exact_genomes:
            assert (ra.genome_nodes == rb.genome_nodes).all()
            assert (ra.genome_outs == rb.genome_outs).all()
        np.testing.assert_allclose(ra.metrics, rb.metrics,
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ra.power_rel, rb.power_rel, rtol=1e-5)
        assert ra.feasible == rb.feasible


@pytest.mark.kernel_diff
def test_batched_matches_serial_per_run(serial_records, batched_result):
    assert N_RUNS >= 12  # ISSUE acceptance: >= 6 configs x 2 seeds
    assert batched_result.completed == N_RUNS
    _assert_records_match(serial_records, batched_result.records)


def test_chunked_equals_unchunked(batched_result):
    # chunk_size 5 forces padding AND multiple chunks over the 12-run grid
    chunked = run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                                SweepConfig(chunk_size=5))
    _assert_records_match(batched_result.records, chunked.records)
    np.testing.assert_array_equal(batched_result.hist_fit, chunked.hist_fit)


@pytest.mark.kernel_diff
def test_run_sweep_api_is_batched(serial_records):
    recs = run_sweep(CFG, CONSTRAINTS, SEEDS,
                     sweep=SweepConfig(chunk_size=7))
    _assert_records_match(serial_records, recs)


def test_checkpoint_resume_mid_grid(tmp_path, batched_result):
    sweep = SweepConfig(chunk_size=4, checkpoint_dir=str(tmp_path))
    partial = run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                                dataclasses.replace(sweep, max_chunks=1))
    assert partial.completed == 4 and len(partial.records) == 4
    # the interrupted state is committed; a fresh call continues mid-grid
    resumed = run_sweep_batched(CFG, CONSTRAINTS, SEEDS, sweep)
    assert resumed.completed == N_RUNS
    _assert_records_match(batched_result.records, resumed.records)
    np.testing.assert_array_equal(batched_result.hist_fit, resumed.hist_fit)


def test_checkpoint_ignored_on_grid_change(tmp_path):
    """A checkpoint from a DIFFERENT grid must not poison the sweep."""
    sweep = SweepConfig(chunk_size=4, checkpoint_dir=str(tmp_path))
    run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                      dataclasses.replace(sweep, max_chunks=1))
    other = run_sweep_batched(CFG, CONSTRAINTS[:2], (5,), sweep)
    assert other.completed == 2
    fresh = run_sweep_batched(CFG, CONSTRAINTS[:2], (5,),
                              SweepConfig(chunk_size=4))
    _assert_records_match(fresh.records, other.records)


def test_histories_consistent(batched_result):
    res = batched_result
    gens = CFG.evolve.generations
    assert res.hist_fit.shape == (N_RUNS, gens)
    assert res.hist_metrics.shape == (N_RUNS, gens, M.N_METRICS)
    # parent fitness is monotone non-increasing wherever finite (1+lambda)
    for r in range(N_RUNS):
        fit = res.hist_fit[r]
        finite = fit[np.isfinite(fit)]
        assert (np.diff(finite) <= 1e-5).all()
    # the last history entry is the returned parent's power
    np.testing.assert_allclose(res.hist_power_rel[:, -1], res.power_rel,
                               rtol=1e-4)


def test_resume_not_shadowed_by_other_grid_checkpoint(tmp_path):
    """A higher-numbered checkpoint of a DIFFERENT grid in the same dir must
    not hide this grid's committed progress (resume scans by fingerprint)."""
    sweep = SweepConfig(chunk_size=4, checkpoint_dir=str(tmp_path))
    run_sweep_batched(CFG, CONSTRAINTS, SEEDS, sweep)        # grid A: step 12
    grid_b = CONSTRAINTS[:4]
    run_sweep_batched(CFG, grid_b, SEEDS,
                      dataclasses.replace(sweep, max_chunks=1))  # B: step 4
    resumed = run_sweep_batched(CFG, grid_b, SEEDS,
                                dataclasses.replace(sweep, max_chunks=1))
    # one more chunk finishes B only if B's step 4 was found under A's step 12
    assert resumed.completed == 8 and resumed.done_mask.all()


@pytest.mark.kernel_diff
def test_sigma_interleaved_grid_matches_serial():
    """Sigma-heterogeneous grids execute sigma-grouped (one compiled program
    per sigma, no padding blowup) but must come back in grid order."""
    cons = [ConstraintSpec(mae=2.0),
            ConstraintSpec(mae=2.0, gauss_sigma=16.0),
            ConstraintSpec(er=50.0),
            ConstraintSpec(er=50.0, gauss_sigma=16.0)]
    serial = run_sweep_serial(CFG, cons, (0,))
    batched = run_sweep_batched(CFG, cons, (0,), SweepConfig(chunk_size=4))
    _assert_records_match(serial, batched.records)
    assert batched.done_mask.all()


def test_plan_chunks_breaks_on_sigma_change():
    sigmas = np.array([1.0, 1.0, 1.0, 2.0, 2.0, 1.0])
    assert plan_chunks(sigmas, 4) == [(0, 3), (3, 5), (5, 6)]
    assert plan_chunks(sigmas, 2) == [(0, 2), (2, 3), (3, 5), (5, 6)]
    assert plan_chunks(np.ones(5), 8) == [(0, 5)]


def test_sweep_grid_order_matches_serial_loop():
    grid = sweep_grid(CONSTRAINTS, SEEDS)
    assert len(grid) == N_RUNS
    assert grid[0] == (CONSTRAINTS[0], 0) and grid[1] == (CONSTRAINTS[0], 1)
    assert grid[2][0] is CONSTRAINTS[1]


# --------------------------------------------------------------------------
# Backend parity (fused pallas kernel path, ISSUE 2)
# --------------------------------------------------------------------------

def _test_layout() -> str:
    """The CI kernel-differential matrix runs the pallas legs once per
    evaluation-grid layout via REPRO_TEST_LAYOUT (default: auto)."""
    env = os.environ.get("REPRO_TEST_LAYOUT")
    return env if env in ("genome_major", "cube_major") else "auto"


def _with_backend(backend: str):
    return dataclasses.replace(
        PAR_CFG, evolve=dataclasses.replace(PAR_CFG.evolve, backend=backend,
                                            layout=_test_layout()))


PAR_CFG = SearchConfig(width=2, kind="add", n_n=40,
                       evolve=EvolveConfig(generations=60, lam=3))
PAR_CONS = [ConstraintSpec(mae=1.0), ConstraintSpec(er=50.0)]
PAR_SEEDS = (0, 1)
PAR_RUNS = len(PAR_CONS) * len(PAR_SEEDS)


def _parity_backends():
    """The CI backend-matrix leg narrows this via REPRO_TEST_BACKEND."""
    env = os.environ.get("REPRO_TEST_BACKEND")
    return (env,) if env in ("jnp", "pallas") else ("jnp", "pallas")


@pytest.mark.kernel_diff
@pytest.mark.parametrize("backend", _parity_backends())
def test_batched_matches_serial_same_backend(backend):
    """Per-backend equivalence oracle: the batched engine reproduces the
    serial loop bit-for-bit with the SAME backend on both sides — for
    "pallas" that pits the fused (runs × λ) kernel dispatch against one
    per-generation λ-population dispatch per run."""
    cfg = _with_backend(backend)
    serial = run_sweep_serial(cfg, PAR_CONS, PAR_SEEDS)
    batched = run_sweep_batched(cfg, PAR_CONS, PAR_SEEDS,
                                SweepConfig(chunk_size=3))  # ragged chunks
    assert batched.completed == PAR_RUNS
    _assert_records_match(serial, batched.records)


@pytest.mark.kernel_diff
@pytest.mark.skipif(os.environ.get("REPRO_TEST_BACKEND") == "jnp",
                    reason="cross-backend test; runs in the pallas CI leg")
def test_sweep_backend_parity_with_resume(tmp_path):
    """run_sweep(backend="pallas") matches backend="jnp" per-run, including
    through a mid-grid checkpoint resume of the pallas sweep.  The grid is
    mae/er-constrained, so selection depends only on exact integer partials
    and the evolved genomes must match bit-for-bit across backends."""
    want = run_sweep_batched(_with_backend("jnp"), PAR_CONS, PAR_SEEDS,
                             SweepConfig(chunk_size=2))
    sweep = SweepConfig(chunk_size=2, checkpoint_dir=str(tmp_path))
    cfg_p = _with_backend("pallas")
    partial = run_sweep_batched(cfg_p, PAR_CONS, PAR_SEEDS,
                                dataclasses.replace(sweep, max_chunks=1))
    assert partial.completed == 2 and len(partial.records) == 2
    resumed = run_sweep_batched(cfg_p, PAR_CONS, PAR_SEEDS, sweep)
    assert resumed.completed == PAR_RUNS
    _assert_records_match(want.records, resumed.records)
    np.testing.assert_array_equal(want.hist_fit, resumed.hist_fit)

"""Deterministic stand-in for ``hypothesis`` on bare environments.

The tier-1 suite property-tests several modules with hypothesis, but the
container image does not ship it.  This shim implements the tiny subset the
suite uses (``given``/``settings`` and the ``integers``/``floats``/``lists``
strategies) by drawing a fixed number of examples from a seeded NumPy
generator, so the tests stay property-style *and* reproducible.  When real
hypothesis is installed the test modules import it instead (see their
try/except imports) and this file is inert.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class st:
    """Subset of ``hypothesis.strategies`` used by this repo's tests."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        # NOTE: deliberately no functools.wraps — the wrapper must expose a
        # zero-arg signature or pytest mistakes drawn params for fixtures.
        def wrapper():
            n = getattr(fn, "_max_examples", 20)
            rng = np.random.default_rng(0xC0FFEE)
            for _ in range(n):
                fn(*[s.draw(rng) for s in strategies])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco

"""Property tests for the Pareto / sweep-results layer (core.pareto)."""
import numpy as np

from repro.core.pareto import (hypervolume_2d, metric_correlations,
                               pareto_front, pareto_points, sweep_fronts)


def _dominates(a, b):
    return (a <= b).all() and (a < b).any()


def test_front_properties_random_clouds():
    rng = np.random.default_rng(0)
    for trial in range(20):
        n = int(rng.integers(1, 40))
        k = int(rng.integers(2, 5))
        pts = rng.uniform(0, 10, size=(n, k))
        mask = pareto_front(pts)
        front = pts[mask]
        assert mask.any()
        # no front point dominates another front point
        for i in range(len(front)):
            for j in range(len(front)):
                if i != j:
                    assert not _dominates(front[i], front[j]), trial
        # every excluded point is dominated by some front point
        for i in np.flatnonzero(~mask):
            assert any(_dominates(f, pts[i]) for f in front), trial


def test_front_never_selects_nonfinite_rows():
    rng = np.random.default_rng(1)
    for trial in range(10):
        pts = rng.uniform(0, 10, size=(12, 2))
        bad = rng.integers(0, 12, size=3)
        pts[bad[0], 0] = np.nan
        pts[bad[1], 1] = np.inf
        pts[bad[2], 0] = -np.inf  # would dominate everything if admitted
        mask = pareto_front(pts)
        assert not mask[bad].any()
        assert np.isfinite(pts[mask]).all()


def test_front_duplicates_and_single_point():
    pts = np.array([[1.0, 2.0], [1.0, 2.0], [3.0, 3.0]])
    mask = pareto_front(pts)
    # duplicates do not dominate each other; one (or both) stays, [3,3] goes
    assert mask[:2].any() and not mask[2]
    assert pareto_front(np.array([[5.0, 5.0]])).all()


def test_hypervolume_staircase_hand_computed():
    # front (1,3),(2,2),(3,1) vs ref (4,4):
    #   (4-1)*(4-3) + (4-2)*(3-2) + (4-3)*(2-1) = 3 + 2 + 1 = 6
    pts = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    assert abs(hypervolume_2d(pts, (4.0, 4.0)) - 6.0) < 1e-12
    # dominated and out-of-reference points change nothing
    noisy = np.vstack([pts, [[3.5, 3.5], [10.0, 0.5], [0.5, 10.0]]])
    assert abs(hypervolume_2d(noisy, (4.0, 4.0)) - 6.0) < 1e-12
    assert hypervolume_2d(np.zeros((0, 2)), (1.0, 1.0)) == 0.0


def test_hypervolume_monotone_under_improvement():
    rng = np.random.default_rng(2)
    pts = rng.uniform(1, 5, size=(15, 2))
    base = hypervolume_2d(pts, (6.0, 6.0))
    better = np.vstack([pts, [[0.5, 0.5]]])  # dominates everything
    assert hypervolume_2d(better, (6.0, 6.0)) >= base


def test_metric_correlations_basic_properties():
    rng = np.random.default_rng(3)
    x = rng.normal(size=100)
    X = np.stack([x, 2.0 * x + 1.0, rng.normal(size=100),
                  np.full(100, 7.0)], axis=1)
    C = metric_correlations(X)
    assert C.shape == (4, 4)
    np.testing.assert_allclose(C, C.T)
    np.testing.assert_allclose(np.diag(C), 1.0)
    assert ((0.0 <= C) & (C <= 1.0 + 1e-12)).all()
    assert C[0, 1] > 0.999            # affine copies correlate perfectly
    assert (C[3, :3] == 0.0).all()    # constant column: 0, not NaN
    # degenerate inputs fall back to identity
    np.testing.assert_allclose(metric_correlations(X[:2]), np.eye(4))


def test_sweep_fronts_shapes_and_membership():
    rng = np.random.default_rng(4)
    power = rng.uniform(0.2, 1.0, size=30)
    metrics = rng.uniform(0, 5.0, size=(30, 7))
    fronts = sweep_fronts(power, metrics, (0, 2))
    assert set(fronts) == {0, 2}
    for idx, front in fronts.items():
        assert front.shape[1] == 2
        assert (np.diff(front[:, 0]) >= 0).all()       # sorted by power
        cloud = np.stack([power, metrics[:, idx]], axis=1)
        pf = pareto_points(cloud)
        np.testing.assert_allclose(front, pf)

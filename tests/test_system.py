"""End-to-end behaviour tests for the paper's system: evolve -> library ->
deploy into an LM (approx matmul) -> train -> serve, plus launcher CLIs."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, SRC


def _run_cli(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-W", "ignore", "-m"] + args,
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_end_to_end_evolve_deploy_train(tmp_path):
    """The paper's full story in miniature: evolve an approximate multiplier
    under combined constraints, deploy its LUT into a quantized matmul, and
    check the model-level error stays bounded."""
    from repro.core.evolve import EvolveConfig
    from repro.core.fitness import ConstraintSpec
    from repro.core.library import (load_library, multiplier_lut,
                                    record_to_genome, save_library)
    from repro.core.search import SearchConfig, run_search
    from repro.core.genome import CGPSpec
    from repro.models import quant

    cfg = SearchConfig(width=8, n_n=400,
                       evolve=EvolveConfig(generations=150, lam=6))
    con = ConstraintSpec(mae=0.05, er=90.0)
    rec, _ = run_search(cfg, con, seed=0)
    assert rec.feasible
    lib_path = str(tmp_path / "lib.json")
    save_library([rec], lib_path)
    lib = load_library(lib_path)
    genome = record_to_genome(lib[0])
    lut = multiplier_lut(genome, CGPSpec(16, 16, 400))
    assert lut.shape == (256, 256)
    # deploy: approximate matmul error bounded by quant error + circuit MAE
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8))
    err = quant.quant_error(x, w, jnp.asarray(lut))
    # MAE<=0.05% of the output range keeps model-level relative error small
    # (the search legitimately exploits looser MAE budgets into circuits
    # whose *relative* matmul error on small-magnitude products is larger)
    assert err < 0.25, err


def test_train_cli_loss_decreases(tmp_path):
    out = _run_cli(["repro.launch.train", "--arch", "llama3_2_1b",
                    "--reduced", "--steps", "30", "--batch", "8",
                    "--seq", "64", "--ckpt-dir", str(tmp_path / "ck")])
    lines = [l for l in out.splitlines() if l.startswith("[train] step")]
    first = float(lines[0].split("loss")[1].split()[0])
    last = float(lines[-1].split("loss")[1].split()[0])
    assert last < first, out


@pytest.mark.slow
def test_train_cli_resume_from_checkpoint(tmp_path):
    ck = str(tmp_path / "ck")
    _run_cli(["repro.launch.train", "--arch", "llama3_2_1b", "--reduced",
              "--steps", "10", "--ckpt-every", "5", "--batch", "4",
              "--seq", "32", "--ckpt-dir", ck])
    out = _run_cli(["repro.launch.train", "--arch", "llama3_2_1b",
                    "--reduced", "--steps", "15", "--ckpt-every", "5",
                    "--batch", "4", "--seq", "32", "--ckpt-dir", ck])
    assert "resumed from step 10" in out


def test_serve_cli():
    out = _run_cli(["repro.launch.serve", "--arch", "llama3_2_1b",
                    "--reduced", "--requests", "4", "--prompt-len", "16",
                    "--gen-len", "8", "--slots", "2"])
    assert "tok/s" in out


def test_evolve_cli(tmp_path):
    out = _run_cli(["repro.launch.evolve", "--width", "4", "--nodes", "130",
                    "--constraint", "mae=2.0,er=80", "--generations", "200",
                    "--lam", "4", "--out", str(tmp_path / "lib.json")])
    rec = json.loads([l for l in out.splitlines() if l.startswith("{")][0])
    assert rec["feasible"]
    assert rec["metrics"]["mae"] <= 2.0 + 1e-3
    assert os.path.exists(tmp_path / "lib.json")


def test_microbatched_train_step_matches_single():
    from repro.configs.base import ModelConfig
    from repro.launch import steps as ST
    from repro.models import model as M
    from repro.optim import OptConfig, init_opt_state
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab=128)
    opt_cfg = OptConfig(weight_decay=0.0)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    opt = init_opt_state(params, opt_cfg)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, 128),
             "targets": jax.random.randint(key, (8, 16), 0, 128)}
    s1 = ST.make_train_step(cfg, opt_cfg, microbatches=1)
    s4 = ST.make_train_step(cfg, opt_cfg, microbatches=4)
    p1, _, m1 = s1(params, opt, batch, jnp.int32(0))
    p4, _, m4 = s4(params, opt, batch, jnp.int32(0))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_chunked_ce_matches_unchunked():
    import dataclasses
    from repro.configs.base import ModelConfig
    from repro.models import model as M
    cfg = ModelConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 32), 0, 128)
    l_full = float(M.lm_loss(params, toks, toks, cfg))
    cfg_c = dataclasses.replace(cfg, loss_vocab_chunk=8)
    l_chunk = float(M.lm_loss(params, toks, toks, cfg_c))
    assert abs(l_full - l_chunk) < 1e-4

"""Differential tests for the fused (runs × λ) batched CGP-evaluation kernel.

Three-way comparison per genome: ``cgp_sim_metrics_batched`` (genome axis on
the Pallas grid) vs the per-genome ``cgp_sim_metrics`` vs the pure-jnp oracle
``ref.cgp_eval_ref`` — across widths, gauss sigmas, block sizes and ragged R
(R not a multiple of the genome-axis pad width).  All integer-valued metric
partials and the per-gate popcounts must be BIT-identical (the split-sum
accumulators are exact in float32); ``rel_sum`` is a float32 division
reduction that XLA may reassociate differently across program shapes, so it
gets allclose.

Also: the exhaustive ``_gate_eval`` truth-table property test and the
interpret-mode auto-detect regression test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container image has no hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.core import gates, golden as G, simulate as S
from repro.core.genome import CGPSpec, Genome, random_genome
from repro.kernels import cgp_sim, ops, ref

pytestmark = pytest.mark.kernel_diff

# bit-identical across batched kernel / per-genome kernel / jnp oracle
EXACT_FIELDS = ("abs_sum", "wce_max", "err_count", "sgn_sum", "acc0_bad",
                "hist", "count")


def _stacked_genomes(spec: CGPSpec, R: int, seed: int = 0) -> Genome:
    return jax.vmap(lambda k: random_genome(k, spec))(
        jax.random.split(jax.random.PRNGKey(seed), R))


@pytest.mark.parametrize("width,n_n,block,R,sigma", [
    (2, 40, 8, 3, 256.0),    # sub-word cube (W = 1), ragged R
    (2, 40, 1, 1, 32.0),     # degenerate single-genome batch
    (4, 120, 2, 5, 32.0),    # many cube blocks, ragged R (pad width 8)
    (4, 120, 8, 8, 48.0),    # W == bw, R exactly on the pad boundary
    (4, 120, 4, 9, 256.0),   # R just past the pad boundary
    (8, 150, 512, 2, 256.0),  # paper-scale cube, lane-aligned block
])
def test_batched_kernel_differential(width, n_n, block, R, sigma):
    spec = CGPSpec(n_i=2 * width, n_o=2 * width, n_n=n_n)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    genomes = _stacked_genomes(spec, R, seed=width * 10 + R)

    # r_tile=8 forced: interpret mode would otherwise auto-select 1 and the
    # ragged-R rows above would never hit the genome-axis pad/slice path
    pb, popb = ops.cgp_eval_batched(genomes, spec, planes, gvals,
                                    gauss_sigma=sigma, block_words=block,
                                    r_tile=8)
    assert popb.shape == (R, n_n)
    for i in range(R):
        gi = jax.tree.map(lambda x: x[i], genomes)
        ps, pops = ops.cgp_eval(gi, spec, planes, gvals, gauss_sigma=sigma,
                                block_words=block)
        pr, popr = ref.cgp_eval_ref(gi, spec, planes, gvals, sigma)
        for name in EXACT_FIELDS:
            got = np.asarray(getattr(pb, name)[i])
            np.testing.assert_array_equal(
                got, np.asarray(getattr(ps, name)),
                err_msg=f"batched vs per-genome kernel: {name} @ genome {i}")
            np.testing.assert_array_equal(
                got, np.asarray(getattr(pr, name)),
                err_msg=f"batched kernel vs jnp oracle: {name} @ genome {i}")
        np.testing.assert_allclose(np.asarray(pb.rel_sum[i]),
                                   np.asarray(pr.rel_sum), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(popb[i]), np.asarray(pops))
        np.testing.assert_array_equal(np.asarray(popb[i]), np.asarray(popr))


@pytest.mark.parametrize("r_tile,R", [
    (8, 5),   # ragged: pad rows recompute the last genome, sliced off
    (8, 8),   # exactly one pad tile, no pad rows
    (4, 9),   # ragged just past a tile boundary
    (1, 3),   # no padding at all (the interpret-mode ops default)
])
def test_batched_raw_rows_match_per_genome_call(r_tile, R):
    """The raw (R, ·) accumulator rows equal R independent per-genome calls —
    including ``rel_sum``: identical kernel, identical block walk.  Covers
    ragged R against the genome-axis pad width ``r_tile``."""
    spec = CGPSpec(n_i=8, n_o=8, n_n=60)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(4, "mul"))
    genomes = _stacked_genomes(spec, R, seed=3)
    batched = cgp_sim.cgp_sim_metrics_batched(
        genomes.nodes, genomes.outs, planes, gvals, n_i=spec.n_i,
        n_n=spec.n_n, n_o=spec.n_o, gauss_sigma=32.0, block_words=4,
        r_tile=r_tile)
    for i in range(R):
        single = cgp_sim.cgp_sim_metrics(
            genomes.nodes[i], genomes.outs[i], planes, gvals, n_i=spec.n_i,
            n_n=spec.n_n, n_o=spec.n_o, gauss_sigma=32.0, block_words=4)
        for got, want, name in zip(batched, single,
                                   ("sums", "wce", "hist", "pops")):
            np.testing.assert_array_equal(np.asarray(got[i]),
                                          np.asarray(want),
                                          err_msg=f"{name} @ genome {i}")


# ----------------------------- _gate_eval ------------------------------------

_LANES = np.arange(32, dtype=np.uint64)
_A_BITS = (_LANES & 1).astype(np.int64)          # lane l: a = l & 1
_B_BITS = ((_LANES >> 1) & 1).astype(np.int64)   # lane l: b = (l >> 1) & 1


def _plane(bits: np.ndarray) -> jax.Array:
    """Pack 32 bits (lane-indexed) into one int32 word."""
    word = (bits.astype(np.uint64) << _LANES).sum() & np.uint64(0xFFFFFFFF)
    return jnp.asarray(np.array([word], np.uint32).view(np.int32)[0])


def _unpack(word) -> np.ndarray:
    return (np.asarray(word).view(np.uint32) >> _LANES.astype(np.uint32)) & 1


def test_gate_eval_all_16_truth_tables_exhaustive():
    """Every possible 4-bit truth table, over all 4 input-bit combinations
    (packed into one word so every combination is evaluated at once)."""
    a, b = _plane(_A_BITS), _plane(_B_BITS)
    packed_lo = sum(tt << (4 * tt) for tt in range(8))
    packed_hi = sum(tt << (4 * (tt - 8)) for tt in range(8, 16))
    for tt in range(16):
        packed, slot = (packed_lo, tt) if tt < 8 else (packed_hi, tt - 8)
        out = cgp_sim._gate_eval(jnp.int32(slot), a, b, tt_packed=packed)
        got = _unpack(out)
        want = (tt >> (_A_BITS + 2 * _B_BITS)) & 1
        np.testing.assert_array_equal(got, want, err_msg=f"truth table {tt}")


def test_gate_eval_library_gates_match_core_gates_tables():
    """The default TT_PACKED path reproduces core.gates truth tables for all
    library gates over all 4 input combinations."""
    a, b = _plane(_A_BITS), _plane(_B_BITS)
    for func in range(gates.N_FUNCS):
        got = _unpack(cgp_sim._gate_eval(jnp.int32(func), a, b))
        want = (gates.TRUTH_TABLES[func] >> (_A_BITS + 2 * _B_BITS)) & 1
        np.testing.assert_array_equal(got, want,
                                      err_msg=gates.GATE_NAMES[func])


@settings(max_examples=32, deadline=None)
@given(st.integers(0, gates.N_FUNCS - 1),
       st.integers(-(2 ** 31), 2 ** 31 - 1),
       st.integers(-(2 ** 31), 2 ** 31 - 1))
def test_gate_eval_random_words_match_numpy_oracle(func, wa, wb):
    a = np.array(wa, np.int64).astype(np.int32)
    b = np.array(wb, np.int64).astype(np.int32)
    got = np.asarray(cgp_sim._gate_eval(jnp.int32(func), jnp.asarray(a),
                                        jnp.asarray(b)))
    want = gates.gate_output_np(np.array(func), a, b)
    assert got == want, (gates.GATE_NAMES[func], hex(a & 0xFFFFFFFF))


# ----------------------- interpret auto-detect fix ---------------------------

def test_interpret_default_pinned_once(monkeypatch):
    """Regression (ISSUE 2): the interpret-mode default is resolved ONCE per
    process and cached.  A backend report that changes afterwards (e.g. a
    ``jax.config`` platform update between traces) must neither flip the
    mode of later traces nor even be consulted again during tracing —
    per-call resolution would bake inconsistent modes into cached traces."""
    saved = ops._INTERPRET_DEFAULT
    try:
        monkeypatch.setattr(ops, "_on_tpu", lambda: False)
        ops._INTERPRET_DEFAULT = None
        assert ops.default_interpret() is True
        monkeypatch.setattr(ops, "_on_tpu", lambda: True)  # report flips
        assert ops.default_interpret() is True             # still pinned

        def boom():
            raise AssertionError("interpret default re-resolved in a trace")

        monkeypatch.setattr(ops, "_on_tpu", boom)
        spec = CGPSpec(n_i=4, n_o=4, n_n=10)
        planes = S.input_planes(spec.n_i)
        gvals = jnp.asarray(G.golden_values(2, "mul"))
        g = random_genome(jax.random.PRNGKey(0), spec)

        @jax.jit
        def probe(nodes, outs):
            partials, _ = ops.cgp_eval(Genome(nodes, outs), spec, planes,
                                       gvals)
            return partials.abs_sum

        probe(g.nodes, g.outs)  # raises iff cgp_eval re-resolves in-trace
    finally:
        ops._INTERPRET_DEFAULT = saved

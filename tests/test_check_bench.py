"""tools/check_bench.py — the CI bench gate (ISSUE 5 acceptance: a
synthetic 2× slowdown against the committed baseline must exit non-zero)."""
import json
import os
import subprocess
import sys

from conftest import REPO

CHECK = os.path.join(REPO, "tools", "check_bench.py")

BASELINE = {
    "sweep": {"n_runs": 6, "serial_runs_per_s": 2.0,
              "batched_jnp_runs_per_s": 20.0,
              "batched_pallas_cube_major_runs_per_s": 10.0},
    "gen": {"generations_per_s": 100.0},
    "results": {"spill_rows_per_s": 1e4, "row_kb": 7.0},
    "eval": {"fused_us_per_eval": 50.0},
    "_meta": {"smoke": True},
}


def _run(tmp_path, current, baseline=BASELINE, extra=()):
    cur, base = tmp_path / "cur.json", tmp_path / "base.json"
    cur.write_text(json.dumps(current))
    base.write_text(json.dumps(baseline))
    return subprocess.run(
        [sys.executable, CHECK, str(cur), "--baseline", str(base), *extra],
        capture_output=True, text=True)


def test_identical_passes(tmp_path):
    proc = _run(tmp_path, BASELINE)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "check_bench OK" in proc.stdout


def test_synthetic_2x_slowdown_fails(tmp_path):
    slow = json.loads(json.dumps(BASELINE))
    for bench in ("sweep", "gen", "results"):
        for k, v in slow[bench].items():
            if k.endswith("_per_s"):
                slow[bench][k] = v / 2          # throughput halves...
    slow["eval"]["fused_us_per_eval"] *= 2      # ...latency doubles
    proc = _run(tmp_path, slow)
    assert proc.returncode != 0, proc.stdout
    assert "FAIL sweep.serial_runs_per_s" in proc.stdout
    assert "FAIL eval.fused_us_per_eval" in proc.stdout
    # shape keys are not performance: n_runs/row_kb never gate
    assert "n_runs" not in proc.stdout and "row_kb" not in proc.stdout


def test_regression_inside_gate_passes(tmp_path):
    ok = json.loads(json.dumps(BASELINE))
    ok["gen"]["generations_per_s"] = 80.0   # -20% < 30% gate
    proc = _run(tmp_path, ok)
    assert proc.returncode == 0, proc.stdout


def test_tighter_gate_catches_it(tmp_path):
    ok = json.loads(json.dumps(BASELINE))
    ok["gen"]["generations_per_s"] = 80.0
    proc = _run(tmp_path, ok, extra=("--max-regression", "0.1"))
    assert proc.returncode != 0


def test_new_and_missing_keys_never_fail(tmp_path):
    cur = json.loads(json.dumps(BASELINE))
    del cur["gen"]                                   # GONE key
    cur["sweep"]["batched_new_leg_runs_per_s"] = 5.0  # NEW key
    proc = _run(tmp_path, cur)
    assert proc.returncode == 0, proc.stdout
    assert "GONE gen.generations_per_s" in proc.stdout
    assert "NEW  sweep.batched_new_leg_runs_per_s" in proc.stdout


def test_missing_baseline_is_not_a_failure(tmp_path):
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(BASELINE))
    proc = subprocess.run(
        [sys.executable, CHECK, str(cur), "--baseline",
         str(tmp_path / "nope.json")], capture_output=True, text=True)
    assert proc.returncode == 0
    assert "no baseline" in proc.stdout

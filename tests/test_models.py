"""Model-layer correctness: attention variants, SSD scan, MoE, quant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (LayerSpec, ModelConfig, MoEConfig, SSMConfig)
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import quant
from repro.models.layers import rms_norm


# ----------------------------- attention ------------------------------------

@pytest.mark.parametrize("shape", [(2, 48, 4, 2, 16), (1, 130, 8, 8, 8),
                                   (3, 33, 4, 1, 32)])
def test_blocked_attention_matches_naive(shape):
    B, S, H, Hkv, D = shape
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    got = A.blocked_attention(q, k, v, causal=True, block_q=16, block_kv=16)
    want = A.naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blocked_attention_q_offset_decodes_suffix():
    B, S, H, D = 1, 32, 2, 8
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    full = A.blocked_attention(q, k, v, causal=True, block_q=8, block_kv=8)
    # last 8 queries with offset should equal the tail of the full result
    tail = A.blocked_attention(q[:, -8:], k, v, causal=True, block_q=8,
                               block_kv=8, q_offset=S - 8)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -8:]),
                               rtol=2e-5, atol=2e-5)


def test_split_kv_decode_matches_single_shard():
    """The flash-decoding LSE merge must equal ordinary decode attention."""
    cfg = ModelConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                      vocab=64)
    key = jax.random.PRNGKey(0)
    params = A.init_attention(key, cfg)
    B, S = 2, 64
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, 32))
    kc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 2, cfg.hd))
    vc = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 2, cfg.hd))
    pos = jnp.asarray([40, 50], jnp.int32)
    out_ref, _, _ = A.decode_self_attention(params, x, kc, vc, pos, cfg)

    # emulate 4 sequence shards with vmap over a fake axis
    n_sh = 4
    S_l = S // n_sh
    kc_s = kc.reshape(B, n_sh, S_l, 2, cfg.hd).transpose(1, 0, 2, 3, 4)
    vc_s = vc.reshape(B, n_sh, S_l, 2, cfg.hd).transpose(1, 0, 2, 3, 4)

    def per_shard(k_l, v_l, shard):
        local_pos = pos - shard * S_l
        gpos = jnp.arange(S_l)[None, :] + shard * S_l
        valid = gpos <= pos[:, None]
        h = rms_norm(x, params["norm"], cfg.norm_eps)
        from repro.models.layers import rope_angles, apply_rope, matmul
        q = matmul(h, params["wq"], cfg).reshape(B, 1, cfg.n_heads, cfg.hd)
        sin, cos = rope_angles(pos[:, None], cfg.hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        return A._partial_decode_attn(q, k_l, v_l, valid, cfg)

    ms, ls, os_ = [], [], []
    for sh in range(n_sh):
        # shard sh does NOT contain the new token here; emulate read-only
        m, l, o = per_shard(kc_s[sh], vc_s[sh], sh)
        ms.append(m), ls.append(l), os_.append(o)
    m_g = jnp.max(jnp.stack(ms), axis=0)
    w = [jnp.exp(m - m_g) for m in ms]
    l_g = sum(l * wi for l, wi in zip(ls, w))
    o_g = sum(o * wi[..., None] for o, wi in zip(os_, w)) / \
        jnp.maximum(l_g, 1e-30)[..., None]
    # compare with the reference path's internal attention (pre-wo):
    # instead compare END-TO-END by re-projecting
    from repro.models.layers import matmul as mm
    out_merge = x + mm(o_g.transpose(0, 2, 1, 3).reshape(B, 1, -1),
                       params["wo"], cfg)
    # reference did cache update (writes new token at pos) — our emulation
    # skipped the write, so rebuild reference without update:
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    from repro.models.layers import rope_angles, apply_rope
    q = mm(h, params["wq"], cfg).reshape(B, 1, cfg.n_heads, cfg.hd)
    sin, cos = rope_angles(pos[:, None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    valid_full = jnp.arange(S)[None, :] <= pos[:, None]
    att = A._masked_decode_attn(q, kc, vc, valid_full, cfg)
    out_direct = x + mm(att.reshape(B, 1, -1), params["wo"], cfg)
    np.testing.assert_allclose(np.asarray(out_merge), np.asarray(out_direct),
                               rtol=2e-5, atol=2e-5)


# ----------------------------- SSD / mamba2 ---------------------------------

def _naive_ssd(xh, dt, Av, Bm, Cm):
    """Direct per-step recurrence oracle (float64-free, fp32)."""
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    state = np.zeros((Bsz, H, P, N), np.float32)
    ys = np.zeros((Bsz, S, H, P), np.float32)
    for t in range(S):
        dA = np.exp(dt[:, t] * Av[None, :])              # (B, H)
        Bh = np.repeat(Bm[:, t], rep, axis=1)            # (B, H, N)
        Ch = np.repeat(Cm[:, t], rep, axis=1)
        state = state * dA[:, :, None, None] + \
            np.einsum("bh,bhp,bhn->bhpn", dt[:, t], xh[:, t], Bh)
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Ch, state)
    return ys, state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive_recurrence(chunk):
    rng = np.random.default_rng(0)
    B, S, H, P, G, N = 2, 24, 4, 8, 2, 16
    xh = rng.normal(size=(B, S, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, size=(B, S, H)).astype(np.float32)
    Av = -rng.uniform(0.5, 2.0, size=(H,)).astype(np.float32)
    Bm = rng.normal(size=(B, S, G, N)).astype(np.float32)
    Cm = rng.normal(size=(B, S, G, N)).astype(np.float32)
    y, state = SSM._ssd_chunked(jnp.asarray(xh), jnp.asarray(dt),
                                jnp.asarray(Av), jnp.asarray(Bm),
                                jnp.asarray(Cm), chunk)
    y_ref, state_ref = _naive_ssd(xh, dt, Av, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunk_size_invariance():
    rng = np.random.default_rng(1)
    B, S, H, P, G, N = 1, 32, 2, 4, 1, 8
    args = (jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32)),
            jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)).astype(np.float32)),
            jnp.asarray(-rng.uniform(0.5, 2, (H,)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(B, S, G, N)).astype(np.float32)))
    y1, s1 = SSM._ssd_chunked(*args, 4)
    y2, s2 = SSM._ssd_chunked(*args, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_ssm_decode_matches_forward():
    cfg = ModelConfig(n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
                      vocab=64, period=(LayerSpec(kind="ssm",
                                                  has_ffn=False),),
                      ssm=SSMConfig(d_state=8, headdim=8, chunk=8,
                                    conv_width=3))
    key = jax.random.PRNGKey(0)
    params = SSM.init_ssm(key, cfg)
    B, S = 2, 17
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 32))
    full, _ = SSM.ssm_forward(params, x, cfg)
    # step-by-step decode
    state = SSM.init_ssm_state(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        y, state = SSM.ssm_decode_step(params, x[:, t:t + 1], state, cfg)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


# ----------------------------- MoE -------------------------------------------

def test_moe_full_capacity_matches_dense_oracle():
    cfg = ModelConfig(n_layers=1, d_model=16, vocab=8,
                      moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=8))
    key = jax.random.PRNGKey(0)
    params = MOE.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 16))
    out, aux = MOE.moe_ffn(params, x, cfg, full_capacity=True)

    # oracle: per-token dense computation of the top-k experts
    h = np.asarray(rms_norm(x, params["norm"], cfg.norm_eps)).reshape(-1, 16)
    router = np.asarray(params["router"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(h @ router), axis=-1)
    w, ids = jax.lax.top_k(probs, 2)
    w = np.asarray(w / w.sum(-1, keepdims=True))
    ids = np.asarray(ids)
    wg = np.asarray(params["w_gate"], np.float32)
    wu = np.asarray(params["w_up"], np.float32)
    wd = np.asarray(params["w_down"], np.float32)

    def expert(e, t):
        gate = h[t] @ wg[e]
        up = h[t] @ wu[e]
        inner = gate / (1 + np.exp(-gate)) * up
        return inner @ wd[e]

    want = np.zeros_like(h)
    for t in range(h.shape[0]):
        for j in range(2):
            want[t] += w[t, j] * expert(ids[t, j], t)
    got = np.asarray(out - x).reshape(-1, 16)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    cfg = ModelConfig(n_layers=1, d_model=16, vocab=8,
                      moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=8,
                                    capacity_factor=0.25))
    key = jax.random.PRNGKey(0)
    params = MOE.init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 16))
    out_drop, _ = MOE.moe_ffn(params, x, cfg)
    out_full, _ = MOE.moe_ffn(params, x, cfg, full_capacity=True)
    # capacity 0.25 must actually change (drop) some outputs
    assert np.abs(np.asarray(out_drop - out_full)).max() > 1e-6


# ----------------------------- quant / approx --------------------------------

def test_approx_matmul_exact_lut_close_to_fp():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
    err = quant.quant_error(x, w, None)  # exact multiplier LUT
    assert err < 0.02, err  # only uint8 quantization noise remains


def test_approx_matmul_zero_point_correction_is_exact():
    """With the exact LUT the emulation must equal integer math exactly."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (8, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 4))
    qx, sx, zx = quant.quantize_u8(x.reshape(-1, 32))
    qw, sw, zw = quant.quantize_u8(w)
    want = (sx * sw * ((np.asarray(qx, np.int64) - float(zx)) @
                       (np.asarray(qw, np.int64) - float(zw))))
    got = quant.approx_matmul(x, w, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_approx_matmul_with_noisy_lut_degrades_gracefully():
    rng = np.random.default_rng(0)
    exact = quant.get_multiplier_lut()
    noisy = np.asarray(exact) + rng.integers(-64, 64, (256, 256))
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (16, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8))
    e_exact = quant.quant_error(x, w, None)
    e_noisy = quant.quant_error(x, w, jnp.asarray(noisy))
    assert e_noisy > e_exact

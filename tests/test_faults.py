"""Crash-consistency fault injection for the sweep pipeline (DESIGN.md §11).

Every test injects a fault at a distinct crash point of the commit path —
rename failures, a poisoned background committer, KeyboardInterrupt between
chunks, a hard process kill mid-commit, damaged shards at restore, a
checkpoint-commit crash, a lost migrant publish — and then proves the §11
contract: no partial shard is ever visible under a committed name, coverage
never references an uncommitted span, and a resumed sweep reproduces the
uninterrupted reference BYTE for byte.

Marked ``faults``: out of the tier-1 default (pytest.ini addopts), run by
``make test-full`` and the CI faults leg.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.checkpoint.store as store_mod
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.results import SweepResultReader
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched

pytestmark = pytest.mark.faults

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
CONSTRAINTS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
               ConstraintSpec(er=50.0)]
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)  # chunk_size 2 -> 3 chunks


def _sweep(results_dir, **kw):
    sweep = SweepConfig(chunk_size=2, keep_history="summary",
                        results_dir=str(results_dir), **kw)
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS, sweep)


def _shards(d):
    return sorted(f for f in os.listdir(d) if f.startswith("shard_")
                  and f.endswith(".npz") and ".tmp." not in f)


def _shard_bytes(d):
    return {f: open(os.path.join(d, f), "rb").read() for f in _shards(d)}


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """The uninterrupted sweep every crashed-then-resumed run must match."""
    d = tmp_path_factory.mktemp("reference")
    res = _sweep(d)
    assert res.completed == N_RUNS
    return str(d)


def _assert_resumes_to_reference(crash_dir, reference):
    res = _sweep(crash_dir)
    assert res.completed == N_RUNS
    a, b = _shard_bytes(reference), _shard_bytes(str(crash_dir))
    assert sorted(a) == sorted(b)
    for name in a:
        assert a[name] == b[name], f"shard bytes differ after resume: {name}"


def _failing_replace(monkeypatch, nth, exc=OSError("injected: disk gone")):
    """Make the ``nth`` shard-commit rename raise — the instant between a
    fully-written tmp file and its atomic publication."""
    orig = os.replace
    seen = []

    def bomb(src, dst):
        if os.path.basename(dst).startswith("shard_"):
            seen.append(dst)
            if len(seen) == nth:
                raise exc
        return orig(src, dst)

    monkeypatch.setattr(os, "replace", bomb)
    return seen


# --------------------------------------------------------------------------
# Crash point 1: rename fails during a synchronous shard commit
# --------------------------------------------------------------------------

def test_sync_commit_rename_crash_then_resume(tmp_path, monkeypatch, reference):
    _failing_replace(monkeypatch, nth=2)
    with pytest.raises(OSError, match="injected"):
        _sweep(tmp_path)
    monkeypatch.undo()
    # the failed span is invisible: one committed shard, no tmp debris
    assert len(_shards(tmp_path)) == 1
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp.npz")]
    assert len(SweepResultReader(str(tmp_path)).spans()) == 1
    _assert_resumes_to_reference(tmp_path, reference)


# --------------------------------------------------------------------------
# Crash point 2: the BACKGROUND committer fails mid-queue — the error
# surfaces on the producer and poisons every later queued commit
# --------------------------------------------------------------------------

def test_async_commit_failure_poisons_queue(tmp_path, monkeypatch, reference):
    _failing_replace(monkeypatch, nth=2)
    with pytest.raises(OSError, match="injected"):
        _sweep(tmp_path, async_commit=True, commit_depth=1)
    monkeypatch.undo()
    # a failed span must never be FOLLOWED by a committed one (the prefix
    # coverage rule would silently orphan the gap): shard 3 was dropped
    committed = _shards(tmp_path)
    assert len(committed) == 1
    assert len(SweepResultReader(str(tmp_path)).spans()) == 1
    _assert_resumes_to_reference(tmp_path, reference)


# --------------------------------------------------------------------------
# Crash point 3: KeyboardInterrupt between chunks of an async sweep — the
# handed-over commits drain before the interrupt propagates
# --------------------------------------------------------------------------

def test_async_keyboard_interrupt_drains_then_resumes(tmp_path, monkeypatch,
                                                      reference):
    import repro.core.sweep as sweep_mod
    real = sweep_mod.characterize_chunk
    calls = []

    def interrupted(*args, **kw):
        calls.append(1)
        if len(calls) == 3:  # chunks 1-2 finished, their commits may still
            raise KeyboardInterrupt  # be queued on the committer
        return real(*args, **kw)

    monkeypatch.setattr(sweep_mod, "characterize_chunk", interrupted)
    with pytest.raises(KeyboardInterrupt):
        _sweep(tmp_path, async_commit=True)
    monkeypatch.undo()
    # both finished chunks were durably committed on the way out
    assert len(_shards(tmp_path)) == 2
    ref = _shard_bytes(reference)
    for name, blob in _shard_bytes(str(tmp_path)).items():
        assert blob == ref[name]
    _assert_resumes_to_reference(tmp_path, reference)


# --------------------------------------------------------------------------
# Crash point 4: hard process kill (os._exit) after the tmp file is written
# but before the rename — no partial shard may be visible to a reader
# --------------------------------------------------------------------------

def test_hard_kill_mid_commit_subprocess(tmp_path, reference):
    code = f"""
import os
orig = os.replace
seen = []
def bomb(src, dst):
    if os.path.basename(dst).startswith("shard_"):
        seen.append(dst)
        if len(seen) == 2:
            os._exit(3)  # power loss: tmp written, never published
    return orig(src, dst)
os.replace = bomb
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched
cfg = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
cons = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
        ConstraintSpec(er=50.0)]
run_sweep_batched(cfg, cons, (0, 1),
                  SweepConfig(chunk_size=2, keep_history="summary",
                              results_dir={str(tmp_path)!r}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-W", "ignore", "-c", code],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 3, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    # the killed commit left at most tmp debris, never a committed name
    assert len(_shards(tmp_path)) == 1
    reader = SweepResultReader(str(tmp_path))
    assert len(reader.spans()) == 1  # coverage excludes the uncommitted span
    _assert_resumes_to_reference(tmp_path, reference)


# --------------------------------------------------------------------------
# Crash points 5+6: a committed-name shard damaged at rest (zero-byte /
# truncated — e.g. pre-§11 rename-without-fsync after power loss) is
# quarantined at restore, logged, and its span re-run
# --------------------------------------------------------------------------

@pytest.mark.parametrize("damage", ["zero", "truncated"])
def test_damaged_shard_quarantined_and_rerun(tmp_path, reference, damage,
                                             capsys):
    _sweep(tmp_path)
    victim = _shards(tmp_path)[1]
    path = os.path.join(str(tmp_path), victim)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(b"" if damage == "zero" else blob[:len(blob) // 2])
    res = _sweep(tmp_path)
    assert res.completed == N_RUNS
    err = capsys.readouterr().err
    assert "quarantined damaged shard" in err
    assert os.path.exists(path + ".corrupt")  # evidence kept, span dropped
    a, b = _shard_bytes(reference), _shard_bytes(str(tmp_path))
    assert sorted(a) == sorted(b)
    for name in a:
        assert a[name] == b[name]


# --------------------------------------------------------------------------
# Crash point 7: checkpoint-commit crash — the previous committed step
# remains the resume point and the finished grid matches the reference
# --------------------------------------------------------------------------

def test_checkpoint_commit_crash_then_resume(tmp_path, monkeypatch):
    ck = str(tmp_path / "ck")
    want = run_sweep_batched(
        CFG, CONSTRAINTS, SEEDS,
        SweepConfig(chunk_size=2, checkpoint_dir=str(tmp_path / "ref")))
    orig = os.rename
    seen = []

    def bomb(src, dst):
        if os.path.basename(dst).startswith("step_"):
            seen.append(dst)
            if len(seen) == 2:
                raise OSError("injected: checkpoint rename lost")
        return orig(src, dst)

    monkeypatch.setattr(os, "rename", bomb)
    with pytest.raises(OSError, match="injected"):
        run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                          SweepConfig(chunk_size=2, checkpoint_dir=ck))
    monkeypatch.undo()
    assert len(store_mod.committed_steps(ck)) == 1  # step 2 never visible
    res = run_sweep_batched(CFG, CONSTRAINTS, SEEDS,
                            SweepConfig(chunk_size=2, checkpoint_dir=ck))
    assert res.completed == N_RUNS
    np.testing.assert_array_equal(res.metrics, want.metrics)
    np.testing.assert_array_equal(res.power_rel, want.power_rel)
    np.testing.assert_array_equal(res.best_fit, want.best_fit)


# --------------------------------------------------------------------------
# Crash point 8: migrant publish lost after the epoch's shards committed —
# the resumed pod republishes identical bytes from the restored rows
# --------------------------------------------------------------------------

def test_lost_migrant_publish_republished_identically(tmp_path):
    res = _sweep(tmp_path, migrate_every=1)
    assert res.completed == N_RUNS
    migrants = sorted(f for f in os.listdir(tmp_path)
                      if f.startswith("migrants_"))
    assert migrants
    victim = os.path.join(str(tmp_path), migrants[0])
    want = open(victim, "rb").read()
    os.remove(victim)  # crash between last shard commit and the publish
    res = _sweep(tmp_path, migrate_every=1)
    assert res.completed == N_RUNS
    assert open(victim, "rb").read() == want


# --------------------------------------------------------------------------
# Durability regression: data reaches disk BEFORE the rename publishes it
# --------------------------------------------------------------------------

def test_atomic_writers_fsync_before_rename(tmp_path, monkeypatch):
    events = []
    orig_fsync, orig_replace = os.fsync, os.replace
    monkeypatch.setattr(os, "fsync",
                        lambda fd: (events.append("fsync"), orig_fsync(fd))[1])
    monkeypatch.setattr(
        os, "replace",
        lambda s, d: (events.append("replace"), orig_replace(s, d))[1])

    store_mod.atomic_save_npz(str(tmp_path / "a.npz"),
                              {"x": np.arange(4)})
    # tmp-file fsync strictly before the publishing rename, dir fsync after
    assert events.index("fsync") < events.index("replace") < len(events) - 1
    assert events.count("fsync") >= 2

    events.clear()
    store_mod.atomic_write_json(str(tmp_path / "a.json"), {"k": 1})
    assert events.index("fsync") < events.index("replace") < len(events) - 1
    assert events.count("fsync") >= 2

"""Core CGP engine: gates, genomes, golden circuits, simulation, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare image: deterministic property-test fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import gates, golden as G, metrics as M, simulate as S
from repro.core.genome import (CGPSpec, Genome, active_mask, critical_path_ps,
                               random_genome, validate_genome)
from repro.core.mutate import mutate
from repro.core.power import circuit_cost_from_probs


# ----------------------------- gates ----------------------------------------

def test_truth_tables_match_python_semantics():
    a = np.array([0, 1, 0, 1], dtype=np.int32)
    b = np.array([0, 0, 1, 1], dtype=np.int32)
    expect = {
        gates.BUF: a, gates.INV: 1 - a, gates.AND: a & b, gates.OR: a | b,
        gates.XOR: a ^ b, gates.NAND: 1 - (a & b), gates.NOR: 1 - (a | b),
        gates.XNOR: 1 - (a ^ b),
    }
    for f, want in expect.items():
        tt = gates.TRUTH_TABLES[f]
        got = (tt >> (a + 2 * b)) & 1
        assert (got == want).all(), gates.GATE_NAMES[f]


def test_tt_packed_consistent():
    for f in range(gates.N_FUNCS):
        assert (gates.TT_PACKED >> (4 * f)) & 0xF == gates.TRUTH_TABLES[f]


# ----------------------------- golden circuits ------------------------------

@pytest.mark.parametrize("width", [2, 3, 4, 6, 8])
def test_array_multiplier_exact(width):
    g, spec = G.array_multiplier(width)
    vals = np.asarray(S.simulate_values(g, spec))
    assert (vals == G.golden_values(width, "mul")).all()


@pytest.mark.parametrize("width", [2, 3, 5, 8])
def test_ripple_adder_exact(width):
    g, spec = G.ripple_carry_adder(width)
    vals = np.asarray(S.simulate_values(g, spec))
    assert (vals == G.golden_values(width, "add")).all()


def test_packed_sim_matches_numpy_oracle():
    spec = CGPSpec(n_i=8, n_o=8, n_n=60)
    for seed in range(5):
        g = random_genome(jax.random.PRNGKey(seed), spec)
        jv = np.asarray(S.simulate_values(g, spec))
        nv = S.simulate_values_np(g, spec)
        assert (jv == nv).all(), seed


# ----------------------------- metrics --------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(2, 24))
@settings(max_examples=15, deadline=None)
def test_metrics_match_numpy_oracle(seed, n_o):
    rng = np.random.default_rng(seed)
    n = 128
    hi = 1 << n_o
    g = rng.integers(0, hi, n).astype(np.int32)
    c = rng.integers(0, hi, n).astype(np.int32)
    got = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(c),
                                           n_o, gauss_sigma=16.0))
    want = M.metrics_np(g, c, n_o, gauss_sigma=16.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n_o", [11, 20, 24])
def test_metrics_oracle_wide_operands_long_slices(n_o):
    """Width-10/12 value ranges on full-cube-sized slices: the regime where
    the historic byte-split _exact_sum silently overflowed float32 (its
    hi-column sums exceed 2^24) and the per-bit popcount path must take
    over (metrics.py ``_exact_sum``)."""
    rng = np.random.default_rng(n_o)
    n = 1 << 16
    hi = 1 << n_o
    g = rng.integers(0, hi, n).astype(np.int32)
    c = rng.integers(0, hi, n).astype(np.int32)
    got = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(c),
                                           n_o, gauss_sigma=256.0))
    want = M.metrics_np(g, c, n_o, gauss_sigma=256.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # the raw sum itself: exact to <= n_bits ulp even at worst-case values
    v = rng.integers(hi // 2, hi, n).astype(np.int32)
    got_sum = float(M._exact_sum(jnp.asarray(v), n_o))
    want_sum = float(v.astype(np.int64).sum())
    assert abs(got_sum - want_sum) <= n_o * np.spacing(np.float32(want_sum))


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_metric_invariants(seed):
    rng = np.random.default_rng(seed)
    g = rng.integers(0, 256, 64).astype(np.int32)
    c = rng.integers(0, 256, 64).astype(np.int32)
    m = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(c), 8))
    assert m[M.MAE] <= m[M.WCE] + 1e-5          # mean |e| <= max |e|
    assert 0.0 <= m[M.ER] <= 100.0
    assert m[M.AVG] <= m[M.MAE] + 1e-5          # |mean e| <= mean |e|
    if (g == c).all():
        assert m[M.ER] == 0 and m[M.WCE] == 0


def test_metrics_zero_for_identical():
    g = np.arange(256, dtype=np.int32)
    m = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(g), 8))
    assert (m[:5] == 0).all() and m[M.ACC0] == 1 and m[M.GAUSS] == 1


def test_finalize_metrics_empty_shard_no_nan():
    """Regression (ISSUE 7): count == 0 (an empty sampled/ragged shard
    partition) used to finalize to 0/0 = NaN vectors that poison fitness
    comparisons (NaN compares false against every threshold)."""
    p = M.error_partials(jnp.zeros(0, jnp.int32), jnp.zeros(0, jnp.int32),
                         16.0)
    assert int(p.count) == 0
    m = np.asarray(M.finalize_metrics(p, 8, 16.0))
    assert np.isfinite(m).all()
    assert (m[:5] == 0).all()           # all-zero sums / max(n, 1)
    se = np.asarray(M.metric_stderr(p, 8))
    assert np.isfinite(se).all() and (se == 0).all()


def test_metrics_np_gauss_slack_matches_finalize():
    """Regression (ISSUE 7): the NumPy oracle hard-coded gauss_slack = 1.0
    while ``finalize_metrics`` accepts a slack parameter — differential
    tests at non-default slack silently diverged.  The GAUSS verdict must
    agree between oracle and jnp path across the slack range, and the
    slack must actually flip the verdict somewhere."""
    rng = np.random.default_rng(3)
    g = rng.integers(0, 256, 512).astype(np.int32)
    # concentrated small errors: violates a tight N(0, 4) envelope head-on
    c = (g - rng.integers(1, 4, 512)).clip(0).astype(np.int32)
    sigma = 4.0
    verdicts = []
    for slack in (0.5, 1.0, 10.0, 1e4):
        p = M.error_partials(jnp.asarray(g), jnp.asarray(c), sigma)
        got = np.asarray(M.finalize_metrics(p, 8, sigma, gauss_slack=slack))
        want = M.metrics_np(g, c, 8, gauss_sigma=sigma, gauss_slack=slack)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                   err_msg=f"slack={slack}")
        verdicts.append(got[M.GAUSS])
    assert min(verdicts) == 0.0 and max(verdicts) == 1.0, \
        "slack sweep must flip the GAUSS verdict"


def test_acc0_detects_violation():
    g = np.zeros(64, dtype=np.int32)
    c = np.zeros(64, dtype=np.int32)
    c[3] = 7
    m = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(c), 8))
    assert m[M.ACC0] == 0


def test_gauss_envelope():
    """Paper Eq. (7): the error histogram (zeros excluded, scaled to all 2^n
    inputs) must stay below the N(0,σ) envelope — so only a SMALL set of
    inputs may carry errors, and large errors only in Gaussian-tail numbers."""
    rng = np.random.default_rng(0)
    n = 4096
    g = rng.integers(100, 200, n).astype(np.int32)
    # 10% of inputs carry small gaussian errors -> fits the sigma=16 envelope
    c = g.copy()
    idx = rng.choice(n, n // 10, replace=False)
    c[idx] = (g[idx] - np.clip(rng.normal(0, 4, idx.size).round(),
                               -40, 40)).astype(np.int32)
    m = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(c), 8,
                                         gauss_sigma=16.0))
    assert m[M.GAUSS] == 1
    # errors on EVERY input must violate (center bins exceed envelope mass)
    c2 = (g + rng.integers(1, 8, n)).astype(np.int32)
    m2 = np.asarray(M.metrics_from_values(jnp.asarray(g), jnp.asarray(c2), 8,
                                          gauss_sigma=4.0))
    assert m2[M.GAUSS] == 0


# ----------------------------- genome ops -----------------------------------

def test_active_mask_vs_bruteforce():
    spec = CGPSpec(n_i=6, n_o=4, n_n=40)
    for seed in range(5):
        g = random_genome(jax.random.PRNGKey(seed), spec)
        got = np.asarray(active_mask(g, spec))
        nodes = np.asarray(g.nodes)
        outs = np.asarray(g.outs)
        want = np.zeros(spec.n_wires, bool)
        stack = list(outs)
        while stack:
            w = stack.pop()
            if want[w]:
                continue
            want[w] = True
            if w >= spec.n_i:
                a, b, f = nodes[w - spec.n_i]
                stack.append(int(a))
                if not gates.ONE_INPUT[f]:
                    stack.append(int(b))
        assert (got == want).all(), seed


@given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.5))
@settings(max_examples=25, deadline=None)
def test_mutation_preserves_legality(seed, rate):
    spec = CGPSpec(n_i=8, n_o=8, n_n=30)
    key = jax.random.PRNGKey(seed)
    g = random_genome(key, spec)
    for i in range(3):
        g = mutate(jax.random.fold_in(key, i), g, spec, rate)
    assert validate_genome(g, spec)


def test_critical_path_positive_and_monotone():
    g, spec = G.array_multiplier(4)
    d_mult = float(critical_path_ps(g, spec))
    a, spec_a = G.ripple_carry_adder(4)
    d_add = float(critical_path_ps(a, spec_a))
    assert d_mult > d_add > 0  # multiplier is deeper than adder


# ----------------------------- power model ----------------------------------

def test_power_drops_when_outputs_truncated():
    g, spec = G.array_multiplier(4)
    planes = S.input_planes(spec.n_i)
    wires = S.simulate_planes(g, spec, planes)
    probs = S.signal_probabilities(wires[spec.n_i:], spec.n_inputs_total)
    full = circuit_cost_from_probs(g, spec, probs)
    # truncate: lowest two outputs wired to a constant-0 node -> fewer active
    import jax.numpy as jnp
    nodes = g.nodes
    const0_idx = spec.n_i  # node 0 made XOR(in0,in0) = 0
    nodes = nodes.at[0].set(jnp.asarray([0, 0, gates.XOR], jnp.int32))
    trunc = Genome(nodes, g.outs.at[0].set(const0_idx).at[1].set(const0_idx))
    wires_t = S.simulate_planes(trunc, spec, planes)
    probs_t = S.signal_probabilities(wires_t[spec.n_i:], spec.n_inputs_total)
    cut = circuit_cost_from_probs(trunc, spec, probs_t)
    assert float(cut.power) < float(full.power)
    assert int(cut.n_active) < int(full.n_active)
    assert float(cut.area) < float(full.area)

"""Phenotype-dedup evaluation cache (DESIGN.md §8).

Three layers, matching the §8 contract:

  1. digest properties — ``phenotype_digests`` is invariant under exactly
     the transformations that leave the active subgraph intact (neutral
     mutations, position shifts, unary second-fan-in junk) and sensitive to
     every change that touches it;
  2. the ``PhenotypeLRU`` container — strict entry bound, eviction order,
     honest counters;
  3. the acceptance bar — ``run_sweep_batched`` with ``dedup`` on is
     BIT-identical to the fused step with it off: records, history arrays,
     and the streamed result shards, with a non-trivial measured hit rate.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import gates
from repro.core.evalcache import CacheStats, PhenotypeLRU
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.genome import (CGPSpec, Genome, active_mask_np,
                               phenotype_digest, phenotype_digests,
                               random_genome)
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched

AND, OR = 2, 3
INV = 1  # one-input gate (gates.ONE_INPUT[INV] == 1)

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=40, lam=4))
CONSTRAINTS = [ConstraintSpec(mae=0.5), ConstraintSpec(er=50.0)]
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)


def _genome(spec, nodes, outs):
    full = np.zeros((spec.n_n, 3), np.int32)
    full[: len(nodes)] = np.asarray(nodes, np.int32)
    return Genome(nodes=full, outs=np.asarray(outs, np.int32))


# --------------------------------------------------------------------------
# 1. digest properties
# --------------------------------------------------------------------------

SPEC = CGPSpec(n_i=2, n_o=1, n_n=4)


def test_digest_deterministic():
    g = _genome(SPEC, [[0, 1, AND]], [SPEC.n_i + 0])
    assert phenotype_digest(g, SPEC) == phenotype_digest(g, SPEC)
    assert len(phenotype_digest(g, SPEC)) == 16


def test_inactive_mutation_is_neutral():
    # node 0 = AND(in0, in1) is the only active node; nodes 1..3 are junk
    base = _genome(SPEC, [[0, 1, AND]], [SPEC.n_i + 0])
    mask = active_mask_np(base.nodes[None], base.outs[None], SPEC)[0]
    assert mask[SPEC.n_i + 0] and not mask[SPEC.n_i + 1 :].any()
    mutated = np.array(base.nodes)
    mutated[2] = [1, SPEC.n_i + 0, OR]  # legal but inactive
    assert (phenotype_digest(Genome(mutated, base.outs), SPEC)
            == phenotype_digest(base, SPEC))


def test_position_shift_same_phenotype_same_digest():
    # the same AND(in0, in1) subgraph living at node 0 vs node 2
    a = _genome(SPEC, [[0, 1, AND]], [SPEC.n_i + 0])
    b = _genome(SPEC, [[1, 0, OR], [0, 0, INV], [0, 1, AND]], [SPEC.n_i + 2])
    assert phenotype_digest(a, SPEC) == phenotype_digest(b, SPEC)


def test_active_change_changes_digest():
    a = _genome(SPEC, [[0, 1, AND]], [SPEC.n_i + 0])
    for nodes, outs in [
        ([[0, 1, OR]], [SPEC.n_i + 0]),    # function change
        ([[1, 0, AND]], [SPEC.n_i + 0]),   # commutative swap NOT folded
        ([[0, 0, AND]], [SPEC.n_i + 0]),   # fan-in change
        ([[0, 1, AND]], [0]),              # output rewired to an input
    ]:
        assert (phenotype_digest(_genome(SPEC, nodes, outs), SPEC)
                != phenotype_digest(a, SPEC))


def test_unary_second_fanin_ignored():
    a = _genome(SPEC, [[0, 0, INV]], [SPEC.n_i + 0])
    b = _genome(SPEC, [[0, 1, INV]], [SPEC.n_i + 0])
    assert gates.ONE_INPUT[INV] == 1
    assert phenotype_digest(a, SPEC) == phenotype_digest(b, SPEC)


def test_batched_digests_match_single():
    import jax

    spec = CGPSpec(n_i=4, n_o=4, n_n=30)
    keys = jax.random.split(jax.random.PRNGKey(7), 8)
    genomes = [random_genome(k, spec) for k in keys]
    nodes = np.stack([np.asarray(g.nodes) for g in genomes])
    outs = np.stack([np.asarray(g.outs) for g in genomes])
    batched = phenotype_digests(nodes, outs, spec)
    assert batched == [phenotype_digest(g, spec) for g in genomes]


# --------------------------------------------------------------------------
# 2. the LRU container
# --------------------------------------------------------------------------

def test_lru_bound_and_eviction_order():
    lru = PhenotypeLRU(max_entries=2)
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1      # refresh "a": "b" is now least recent
    lru.put("c", 3)
    assert len(lru) == 2
    assert "b" not in lru and lru.get("b") is None
    assert lru.get("a") == 1 and lru.get("c") == 3
    assert lru.stats.evictions == 1 and lru.stats.inserts == 3


def test_lru_counters_and_hit_rate():
    st = CacheStats(candidates=10, evaluated=3)
    assert st.hit_rate == pytest.approx(0.7)
    assert CacheStats().hit_rate == 0.0
    d = st.as_dict()
    assert d["candidates"] == 10 and d["hit_rate"] == pytest.approx(0.7)
    assert d["overwrites"] == 0
    with pytest.raises(ValueError):
        PhenotypeLRU(max_entries=0)


def test_lru_overwrite_not_counted_as_insert():
    """Regression (ISSUE 7): ``put`` on an existing key used to bump
    ``inserts``, breaking the inserts == live entries + evictions
    accounting the hit-rate reports are sanity-checked against."""
    lru = PhenotypeLRU(max_entries=2)
    lru.put("a", 1)
    lru.put("a", 2)          # overwrite, NOT an insert
    lru.put("b", 3)
    lru.put("c", 4)          # evicts "a"
    st = lru.stats
    assert st.inserts == 3
    assert st.overwrites == 1
    assert st.evictions == 1
    # counter consistency: every insert is either still live or was evicted
    assert st.inserts == len(lru) + st.evictions
    assert lru.get("c") == 4 and lru.get("a") is None
    assert st.as_dict()["overwrites"] == 1


# --------------------------------------------------------------------------
# 3. acceptance: dedup on == dedup off, bit for bit
# --------------------------------------------------------------------------

def _sweep(dedup, results_dir=None):
    return run_sweep_batched(
        CFG, CONSTRAINTS, SEEDS,
        SweepConfig(chunk_size=N_RUNS, dedup=dedup, results_dir=results_dir))


@pytest.fixture(scope="module")
def off_on(tmp_path_factory):
    dirs = [str(tmp_path_factory.mktemp(f"dedup_{tag}"))
            for tag in ("off", "on")]
    return (_sweep(False, dirs[0]), _sweep(True, dirs[1]), dirs)


def test_dedup_records_bit_identical(off_on):
    off, on, _ = off_on
    assert on.completed == off.completed == N_RUNS
    for ra, rb in zip(off.records, on.records):
        assert ra.constraint == rb.constraint and ra.seed == rb.seed
        assert np.array_equal(ra.genome_nodes, rb.genome_nodes)
        assert np.array_equal(ra.genome_outs, rb.genome_outs)
        assert np.array_equal(ra.metrics, rb.metrics)
        assert ra.power_rel == rb.power_rel
        assert ra.feasible == rb.feasible


def test_dedup_arrays_and_history_bit_identical(off_on):
    off, on, _ = off_on
    for field in ("thresholds", "metrics", "power_rel", "feasible",
                  "best_fit", "hist_power_rel", "hist_fit", "hist_metrics",
                  "done_mask"):
        a, b = getattr(off, field), getattr(on, field)
        assert np.array_equal(a, b), field


def test_dedup_shards_bit_identical(off_on):
    off, on, dirs = off_on
    shards = sorted(f for f in os.listdir(dirs[0]) if f.endswith(".npz"))
    assert shards == sorted(f for f in os.listdir(dirs[1])
                            if f.endswith(".npz")) and shards
    for name in shards:
        with np.load(os.path.join(dirs[0], name)) as za, \
                np.load(os.path.join(dirs[1], name)) as zb:
            assert sorted(za.files) == sorted(zb.files)
            for key in za.files:
                assert np.array_equal(za[key], zb[key]), (name, key)


def test_dedup_hit_rate_nontrivial(off_on):
    off, on, _ = off_on
    assert off.dedup_stats is None
    st = on.dedup_stats
    assert st["candidates"] == N_RUNS * CFG.evolve.lam \
        * CFG.evolve.generations
    assert 0.0 < st["hit_rate"] < 1.0
    assert st["evaluated"] + st["lru_hits"] + st["dup_hits"] \
        == st["candidates"]
    assert st["hit_rate"] > 0.2  # neutral-heavy by construction


def test_dedup_knob_inherits_evolve_config():
    cfg = dataclasses.replace(
        CFG, evolve=dataclasses.replace(CFG.evolve, generations=2,
                                        dedup=True))
    res = run_sweep_batched(cfg, CONSTRAINTS[:1], (0,),
                            SweepConfig(chunk_size=1))
    assert res.dedup_stats is not None  # SweepConfig.dedup=None defers
    # explicit False overrides the EvolveConfig default: the dedup/model_axis
    # incompatibility (diagnosed before the mesh check) is NOT tripped, so
    # the error is the mesh one
    with pytest.raises(ValueError, match="mesh"):
        run_sweep_batched(cfg, CONSTRAINTS[:1], (0,),
                          SweepConfig(chunk_size=1, dedup=False,
                                      model_axis="model"))


def test_dedup_refuses_model_axis():
    with pytest.raises(ValueError, match="dedup"):
        run_sweep_batched(CFG, CONSTRAINTS[:1], (0,),
                          SweepConfig(chunk_size=1, dedup=True,
                                      model_axis="model"))


def test_dedup_cache_size_validated():
    with pytest.raises(ValueError):
        SweepConfig(dedup_cache_size=0)

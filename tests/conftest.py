"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single device; distributed tests spawn subprocesses with their own flags."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python code in a subprocess with N fake XLA host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-W", "ignore", "-c", code],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout

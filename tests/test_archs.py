"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs forward + one train step on CPU,
asserting output shapes and the absence of NaNs (task deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as B
from repro.launch import steps as ST
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state


# the biggest reduced configs dominate tier-1 wall clock (jamba alone is
# ~1 min of trace+train on a stock CPU box) — they ride in CI's full run
_HEAVY_ARCHS = {"jamba_1_5_large_398b", "kimi_k2_1t_a32b", "mamba2_1_3b",
                "stablelm_12b", "phi4_mini_3_8b", "llama3_2_vision_11b"}


def _arch_params(arch_ids):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
            else a for a in arch_ids]


@pytest.mark.parametrize("arch_id", _arch_params(B.ARCH_IDS))
def test_arch_smoke_forward_and_train_step(arch_id):
    mod = B.get_arch(arch_id)
    cfg: B.ModelConfig = mod.reduced()
    assert cfg.n_layers % len(cfg.period) == 0
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    Bsz, S = 2, 32
    if cfg.frontend == "audio":
        toks = jax.random.randint(key, (Bsz, S, cfg.n_codebooks), 0,
                                  cfg.vocab)
    else:
        toks = jax.random.randint(key, (Bsz, S), 0, cfg.vocab)
    img = (jax.random.normal(key, (Bsz, cfg.n_img_tokens, cfg.d_model))
           if cfg.frontend == "vision" else None)

    logits, aux = M.forward_train(params, toks, cfg, image_embeds=img)
    if cfg.frontend == "audio":
        assert logits.shape == (Bsz, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (Bsz, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one full train step (loss + grads + optimizer update)
    opt_cfg = OptConfig(name=getattr(mod, "OPTIMIZER", "adamw"),
                        total_steps=10)
    opt_state = init_opt_state(params, opt_cfg)
    step = ST.make_train_step(cfg, opt_cfg)
    batch = {"tokens": toks, "targets": toks}
    if img is not None:
        batch["image_embeds"] = img
    params2, opt2, metrics = step(params, opt_state, batch, jnp.int32(0))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # parameters must actually change
    delta = max(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch_id", _arch_params(
    ["mamba2_1_3b", "jamba_1_5_large_398b", "musicgen_large",
     "llama3_2_vision_11b"]))
def test_arch_smoke_decode_consistency(arch_id):
    """prefill + decode_step equals full forward at the last position."""
    mod = B.get_arch(arch_id)
    cfg: B.ModelConfig = mod.reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    Bsz, S = 2, 24
    if cfg.frontend == "audio":
        toks = jax.random.randint(key, (Bsz, S, cfg.n_codebooks), 0,
                                  cfg.vocab)
    else:
        toks = jax.random.randint(key, (Bsz, S), 0, cfg.vocab)
    img = (jax.random.normal(key, (Bsz, cfg.n_img_tokens, cfg.d_model))
           if cfg.frontend == "vision" else None)
    logits, _ = M.forward_train(params, toks, cfg, image_embeds=img)
    _, cache = M.prefill(params, toks[:, :S - 1], cfg, max_len=S,
                         image_embeds=img)
    pos = jnp.full((Bsz,), S - 1, jnp.int32)
    logits_d, _ = M.decode_step(params, cache, toks[:, S - 1:], pos, cfg)
    err = np.abs(np.asarray(logits_d[:, 0], np.float32) -
                 np.asarray(logits[:, S - 1], np.float32)).max()
    assert err < 1e-3, (arch_id, err)


def test_full_configs_match_assignment_table():
    """The FULL configs must carry the exact assigned hyperparameters."""
    want = {
        "mamba2_1_3b": dict(n_layers=48, d_model=2048, vocab=50280),
        "phi4_mini_3_8b": dict(n_layers=32, d_model=3072, n_heads=24,
                               n_kv_heads=8, d_ff=8192, vocab=200064),
        "stablelm_1_6b": dict(n_layers=24, d_model=2048, n_heads=32,
                              n_kv_heads=32, d_ff=5632, vocab=100352),
        "stablelm_12b": dict(n_layers=40, d_model=5120, n_heads=32,
                             n_kv_heads=8, d_ff=13824, vocab=100352),
        "llama3_2_1b": dict(n_layers=16, d_model=2048, n_heads=32,
                            n_kv_heads=8, d_ff=8192, vocab=128256),
        "qwen3_moe_30b_a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab=151936),
        "kimi_k2_1t_a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                                n_kv_heads=8, vocab=163840),
        "jamba_1_5_large_398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=24576, vocab=65536),
        "llama3_2_vision_11b": dict(n_layers=40, d_model=4096, n_heads=32,
                                    n_kv_heads=8, d_ff=14336, vocab=128256),
        "musicgen_large": dict(n_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=2048),
    }
    for arch, fields in want.items():
        cfg = B.get_arch(arch).CONFIG
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # MoE structure
    assert B.get_arch("qwen3_moe_30b_a3b").CONFIG.moe.n_experts == 128
    assert B.get_arch("qwen3_moe_30b_a3b").CONFIG.moe.top_k == 8
    assert B.get_arch("kimi_k2_1t_a32b").CONFIG.moe.n_experts == 384
    assert B.get_arch("jamba_1_5_large_398b").CONFIG.moe.n_experts == 16
    assert B.get_arch("jamba_1_5_large_398b").CONFIG.moe.top_k == 2
    assert B.get_arch("mamba2_1_3b").CONFIG.ssm.d_state == 128
    # jamba 1:7 attention ratio in the period
    period = B.get_arch("jamba_1_5_large_398b").CONFIG.period
    assert sum(1 for s in period if s.kind == "attn") == 1
    assert len(period) == 8
    # musicgen codebooks
    assert B.get_arch("musicgen_large").CONFIG.n_codebooks == 4


def test_param_counts_in_expected_range():
    """Abstract param counts should be near the advertised model sizes."""
    expect = {"llama3_2_1b": (1.0e9, 1.8e9),
              "phi4_mini_3_8b": (3.0e9, 4.6e9),
              "stablelm_1_6b": (1.2e9, 2.1e9),
              "stablelm_12b": (10e9, 14e9),
              "qwen3_moe_30b_a3b": (25e9, 34e9),
              "kimi_k2_1t_a32b": (0.9e12, 1.15e12),
              "jamba_1_5_large_398b": (330e9, 430e9),
              "mamba2_1_3b": (1.0e9, 1.6e9),
              "musicgen_large": (1.4e9, 2.6e9),
              "llama3_2_vision_11b": (8.5e9, 12e9)}
    for arch, (lo, hi) in expect.items():
        cfg = B.get_arch(arch).CONFIG
        sds = ST.abstract_params(cfg)
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(sds))
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)

"""Pod-sharded sweep execution (DESIGN.md §6) vs the single-host path.

Pods are process-level: each pod runs its own ``run_sweep_batched`` with a
disjoint round-robin slice of the chunk plan against a SHARED results_dir.
Because every chunk's bytes are a deterministic function of the
fingerprinted grid, the pod-sharded shard set must be BIT-identical to the
single-host one — file names and bytes — and any pod must resume from a
partial per-pod shard set (coverage with global gaps).  The host-local
multi-device mesh legs live in ``test_distributed.py``.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.results import (SweepResultReader, pod_partition,
                                pod_prefix_spans)
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched

CFG = SearchConfig(width=2, kind="add", n_n=40,
                   evolve=EvolveConfig(generations=40, lam=3))
CONSTRAINTS = [ConstraintSpec(mae=1.0), ConstraintSpec(mae=2.0),
               ConstraintSpec(er=50.0)]
SEEDS = (0, 1)
N_RUNS = len(CONSTRAINTS) * len(SEEDS)  # chunk_size 2 -> 3 chunks


def _sweep(results_dir, **kw):
    sweep = SweepConfig(chunk_size=2, keep_history="summary",
                        results_dir=str(results_dir), **kw)
    return run_sweep_batched(CFG, CONSTRAINTS, SEEDS, sweep)


def _shard_bytes(d):
    return {f: open(os.path.join(d, f), "rb").read()
            for f in os.listdir(d) if f.startswith("shard_")}


@pytest.fixture(scope="module")
def single_host(tmp_path_factory):
    d = tmp_path_factory.mktemp("single")
    res = _sweep(d)
    assert res.completed == N_RUNS
    return str(d), res


def test_pod_sharded_shards_bit_identical(tmp_path, single_host):
    """Two pods against one shared dir == the single-host shard set, byte
    for byte (the ISSUE 4 acceptance bit-identity)."""
    sd, want = single_host
    p0 = _sweep(tmp_path, n_pods=2, pod_index=0)
    assert 0 < p0.completed < N_RUNS  # pod 0 owns chunks 0 and 2 only
    assert p0.done_mask.sum() == p0.completed
    p1 = _sweep(tmp_path, n_pods=2, pod_index=1)
    assert p1.completed == N_RUNS and p1.done_mask.all()
    a, b = _shard_bytes(sd), _shard_bytes(str(tmp_path))
    assert sorted(a) == sorted(b)
    for name in a:
        assert a[name] == b[name], f"shard bytes differ: {name}"
    # per-run results identical through the reader
    ra, rb = SweepResultReader(sd), SweepResultReader(str(tmp_path))
    assert rb.n_pods == 2 and rb.completed == N_RUNS
    sa, sb = ra.summary(), rb.summary()
    for key in sa:
        np.testing.assert_array_equal(sa[key], sb[key])
    for (rowa, ha), (rowb, hb) in zip(ra.iter_history(), rb.iter_history()):
        np.testing.assert_array_equal(rowa, rowb)
        for k in ha:
            np.testing.assert_array_equal(ha[k], hb[k])


def test_pod_resume_from_partial_pod_prefixes(tmp_path, single_host):
    """A results_dir holding only pod 1's work (a global GAP at chunk 0) is
    a valid resume point: the reader reports exactly pod 1's coverage, pod
    1 re-runs nothing, and pod 0 completes the grid."""
    sd, want = single_host
    p1 = _sweep(tmp_path, n_pods=2, pod_index=1)  # only chunk 1 -> rows 2:4
    assert p1.completed == 2
    reader = SweepResultReader(str(tmp_path))
    assert reader.spans() == [(2, 4)] and reader.completed == 2
    assert reader.done_mask().sum() == 2
    again = _sweep(tmp_path, n_pods=2, pod_index=1)
    assert again.runs_per_sec == 0.0  # nothing left in pod 1's slice
    # an interrupted pod 0 resumes from its own per-pod prefix
    part0 = _sweep(tmp_path, n_pods=2, pod_index=0, max_chunks=1)
    assert part0.completed == 4  # pod1's chunk + pod0's first
    full = _sweep(tmp_path, n_pods=2, pod_index=0)
    assert full.completed == N_RUNS and full.done_mask.all()
    np.testing.assert_array_equal(full.metrics, want.metrics)
    a, b = _shard_bytes(sd), _shard_bytes(str(tmp_path))
    assert a.keys() == b.keys() and all(a[k] == b[k] for k in a)


def test_pod_result_covers_other_pods_restored_rows(tmp_path, single_host):
    """Each pod's SweepResult reflects total committed coverage, not just
    its own slice — pod 1 starting after pod 0 sees pod 0's rows."""
    _, want = single_host
    _sweep(tmp_path, n_pods=2, pod_index=0)
    p1 = _sweep(tmp_path, n_pods=2, pod_index=1)
    assert p1.completed == N_RUNS
    np.testing.assert_array_equal(p1.metrics, want.metrics)
    np.testing.assert_array_equal(p1.feasible, want.feasible)


def test_multi_pod_config_guards(tmp_path):
    with pytest.raises(ValueError, match="results_dir"):
        SweepConfig(n_pods=2)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        SweepConfig(n_pods=2, results_dir=str(tmp_path),
                    checkpoint_dir=str(tmp_path))
    with pytest.raises(ValueError, match="pod_index"):
        SweepConfig(n_pods=2, results_dir=str(tmp_path), pod_index=2)
    with pytest.raises(ValueError, match="n_pods"):
        SweepConfig(n_pods=0)


def test_pod_count_mismatch_refused(tmp_path):
    """The manifest pins n_pods: relaunching the same grid with a different
    pod partition must be an explicit reset, not silent drift."""
    _sweep(tmp_path, n_pods=2, pod_index=0)
    with pytest.raises(ValueError, match="n_pods"):
        _sweep(tmp_path)  # n_pods=1 against a 2-pod directory


def test_writer_pod_spans_filter(tmp_path):
    """The writer's per-pod span filter follows the manifest-pinned plan
    (and refuses to guess when no plan was pinned)."""
    from repro.core.results import SweepResultWriter
    kw = dict(grid_fingerprint="fp", grid_meta=[], n_runs=4, gens=8,
              n_n=10, n_o=4, keep_history="none", chunk_size=2)
    planned = SweepResultWriter(str(tmp_path / "a"), n_pods=2,
                                chunk_spans=[(0, 2), (2, 4)], **kw)
    assert planned.pod_spans(0) == [(0, 2)]
    assert planned.pod_spans(1) == [(2, 4)]
    planless = SweepResultWriter(str(tmp_path / "b"), **kw)
    with pytest.raises(ValueError, match="chunk_spans"):
        planless.pod_spans(0)


def test_pod_partition_round_robin():
    spans = [(0, 2), (2, 4), (4, 6), (6, 7)]
    assert pod_partition(spans, 1) == [spans]
    assert pod_partition(spans, 2) == [[(0, 2), (4, 6)], [(2, 4), (6, 7)]]
    assert pod_partition(spans, 3) == [[(0, 2), (6, 7)], [(2, 4)], [(4, 6)]]
    with pytest.raises(ValueError, match="n_pods"):
        pod_partition(spans, 0)


def test_pod_prefix_spans_union_of_per_pod_prefixes():
    plan = [(0, 2), (2, 4), (4, 6), (6, 8)]
    # n_pods=1 reduces to the global contiguous prefix
    assert pod_prefix_spans([(0, 2), (4, 6)], plan, 1) == [(0, 2)]
    # pod 0 owns (0,2),(4,6); pod 1 owns (2,4),(6,8)
    assert pod_prefix_spans([(0, 2), (2, 4)], plan, 2) == [(0, 2), (2, 4)]
    # a gap in pod 0's OWN sequence orphans its later span...
    assert pod_prefix_spans([(4, 6), (2, 4)], plan, 2) == [(2, 4)]
    # ...but pod 1 running ahead is fine (global gaps tolerated)
    assert pod_prefix_spans([(2, 4), (6, 8)], plan, 2) == [(2, 4), (6, 8)]
    # spans outside the plan are ignored entirely
    assert pod_prefix_spans([(1, 3)], plan, 2) == []

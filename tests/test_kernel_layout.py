"""Differential parity tests for the cube-major evaluation layout and the
kernel-layout tuning subsystem (DESIGN.md §7).

The cube-major grid (cube axis outer, genome axis inner, per-genome
accumulators in flushed VMEM scratch) must be BIT-identical to the
genome-major grid — including the float32 ``rel_sum`` row, because both
layouts accumulate each genome's cube blocks in the same ascending order —
and bit-identical to the serial jnp oracle on every integer-exact field,
across widths × ragged R × block sizes.  Layout is a pure execution knob:
a sweep checkpointed under one layout resumes under the other with
identical results, and the cube-shard psum/pmax contract (DESIGN.md §6.4)
holds on the transposed grid too.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

from repro.core import golden as G, simulate as S
from repro.core.genome import CGPSpec, Genome, random_genome
from repro.kernels import cgp_sim, ops, ref, tune

pytestmark = pytest.mark.kernel_diff

# bit-identical across layouts / kernels / the jnp oracle
EXACT_FIELDS = ("abs_sum", "wce_max", "err_count", "sgn_sum", "acc0_bad",
                "hist", "count")


def _stacked_genomes(spec: CGPSpec, R: int, seed: int = 0) -> Genome:
    return jax.vmap(lambda k: random_genome(k, spec))(
        jax.random.split(jax.random.PRNGKey(seed), R))


@pytest.mark.parametrize("width,n_n,block,R,sigma", [
    (2, 40, 8, 3, 256.0),    # sub-word cube (W = 1 block), ragged R
    (4, 120, 2, 5, 32.0),    # many cube blocks, ragged R (pad width 8)
    (4, 120, 8, 8, 48.0),    # W == bw, R exactly on the pad boundary
    (4, 120, 4, 9, 256.0),   # R just past the pad boundary
    (8, 150, 512, 2, 256.0),  # paper-scale cube, lane-aligned block
])
def test_cube_major_bit_identical_to_genome_major(width, n_n, block, R,
                                                  sigma):
    """Raw accumulator outputs match across layouts bit-for-bit (ALL four
    arrays, including the float32 rel_sum row of ``sums``: identical
    per-genome block order), with the genome-axis pad path forced."""
    spec = CGPSpec(n_i=2 * width, n_o=2 * width, n_n=n_n)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, "mul"))
    genomes = _stacked_genomes(spec, R, seed=width * 100 + R)
    kw = dict(n_i=spec.n_i, n_n=spec.n_n, n_o=spec.n_o, gauss_sigma=sigma,
              block_words=block, r_tile=8)
    gm = cgp_sim.cgp_sim_metrics_batched(
        genomes.nodes, genomes.outs, planes, gvals, layout="genome_major",
        **kw)
    cm = cgp_sim.cgp_sim_metrics_batched(
        genomes.nodes, genomes.outs, planes, gvals, layout="cube_major",
        **kw)
    for got, want, name in zip(cm, gm, ("sums", "wce", "hist", "pops")):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"cube vs genome major: {name}")

    # ... and against the serial jnp oracle per genome (exact fields)
    pc, popc = ops.cgp_eval_batched(genomes, spec, planes, gvals,
                                    gauss_sigma=sigma, block_words=block,
                                    layout="cube_major")
    for i in range(R):
        gi = jax.tree.map(lambda x: x[i], genomes)
        pr, popr = ref.cgp_eval_ref(gi, spec, planes, gvals, sigma)
        for name in EXACT_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(pc, name)[i]),
                np.asarray(getattr(pr, name)),
                err_msg=f"cube-major vs jnp oracle: {name} @ genome {i}")
        np.testing.assert_allclose(np.asarray(pc.rel_sum[i]),
                                   np.asarray(pr.rel_sum), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(popc[i]), np.asarray(popr))


def test_rejects_unknown_layout():
    spec = CGPSpec(n_i=4, n_o=4, n_n=10)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(2, "mul"))
    g = _stacked_genomes(spec, 1)
    with pytest.raises(ValueError, match="layout"):
        cgp_sim.cgp_sim_metrics_batched(
            g.nodes, g.outs, planes, gvals, n_i=spec.n_i, n_n=spec.n_n,
            n_o=spec.n_o, layout="auto")  # "auto" resolves upstream only


# --------------------------------------------------------------------------
# Sweep-level parity: cross-layout checkpoint resume
# --------------------------------------------------------------------------

@pytest.mark.skipif(os.environ.get("REPRO_TEST_BACKEND") == "jnp",
                    reason="layout is a pallas-path knob; runs in the "
                           "pallas CI legs")
def test_checkpoint_resume_across_layouts(tmp_path):
    """A mid-sweep checkpoint written under genome-major resumes under
    cube-major (and the reverse) with results bit-identical to a
    single-layout sweep: layout is NOT part of the grid fingerprint."""
    from repro.core.evolve import EvolveConfig
    from repro.core.fitness import ConstraintSpec
    from repro.core.search import SearchConfig
    from repro.core.sweep import SweepConfig, run_sweep_batched

    cfg = SearchConfig(width=2, kind="add", n_n=40,
                       evolve=EvolveConfig(generations=40, lam=3,
                                           backend="pallas"))
    cons = [ConstraintSpec(mae=1.0), ConstraintSpec(er=50.0)]
    seeds = (0, 1)
    want = run_sweep_batched(cfg, cons, seeds,
                             SweepConfig(chunk_size=2,
                                         layout="genome_major"))

    for first, second in (("genome_major", "cube_major"),
                          ("cube_major", "genome_major")):
        ckpt = str(tmp_path / f"{first}-to-{second}")
        partial = run_sweep_batched(
            cfg, cons, seeds, SweepConfig(chunk_size=2, checkpoint_dir=ckpt,
                                          layout=first, max_chunks=1))
        assert partial.completed == 2
        resumed = run_sweep_batched(
            cfg, cons, seeds, SweepConfig(chunk_size=2, checkpoint_dir=ckpt,
                                          layout=second))
        assert resumed.completed == want.n_runs
        for ra, rb in zip(want.records, resumed.records):
            assert ra.constraint == rb.constraint and ra.seed == rb.seed
            assert (ra.genome_nodes == rb.genome_nodes).all()
            assert (ra.genome_outs == rb.genome_outs).all()
            assert ra.feasible == rb.feasible
        np.testing.assert_array_equal(want.hist_fit, resumed.hist_fit)


# --------------------------------------------------------------------------
# Cube-shard psum/pmax contract on the transposed grid (DESIGN.md §6.4)
# --------------------------------------------------------------------------

def test_sharded_cube_major_psum_contract():
    """Under input-space sharding the cube-major kernel combines per-genome
    accumulators across the mesh axis exactly like genome-major: integer
    fields bit-identical to the unsharded dispatch, rel_sum
    reassociation-close, and the two sharded layouts bit-identical to each
    other (identical shard-local block order + identical psum order)."""
    out = run_subprocess("""
import sys; sys.path.insert(0, 'src')
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import golden as G, simulate as S
from repro.core.genome import CGPSpec, random_genome
from repro.kernels import cgp_sim

mesh = jax.make_mesh((2,), ('model',))
spec = CGPSpec(n_i=8, n_o=8, n_n=60)
planes = S.input_planes(spec.n_i)
gvals = jnp.asarray(G.golden_values(4, 'mul'))
genomes = jax.vmap(lambda k: random_genome(k, spec))(
    jax.random.split(jax.random.PRNGKey(1), 5))
kw = dict(n_i=spec.n_i, n_n=spec.n_n, n_o=spec.n_o, gauss_sigma=32.0,
          block_words=2, r_tile=8)
want = cgp_sim.cgp_sim_metrics_batched(
    genomes.nodes, genomes.outs, planes, gvals, layout='cube_major', **kw)

def sharded(layout):
    def local(nodes, outs, pln, gv):
        return cgp_sim.cgp_sim_metrics_batched_sharded(
            nodes, outs, pln, gv, axis_name='model', layout=layout, **kw)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(), P(), P(None, 'model'), P('model')),
                   out_specs=(P(), P(), P(), P()), check_rep=False)
    return fn(genomes.nodes, genomes.outs, planes, gvals)

got_cm, got_gm = sharded('cube_major'), sharded('genome_major')
REL = cgp_sim.REL_SUM
for w, g, name in zip(want, got_cm, ('sums', 'wce', 'hist', 'pops')):
    w, g = np.asarray(w), np.asarray(g)
    if name == 'sums':
        np.testing.assert_allclose(g[:, REL], w[:, REL], rtol=1e-5)
        exact = [i for i in range(w.shape[1]) if i != REL]
        np.testing.assert_array_equal(g[:, exact], w[:, exact],
                                      err_msg=name)
    else:
        np.testing.assert_array_equal(g, w, err_msg=name)
for a, b, name in zip(got_cm, got_gm, ('sums', 'wce', 'hist', 'pops')):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                  err_msg='sharded layouts differ: ' + name)
print('SHARDED-CUBE-MAJOR-OK')
""", devices=2)
    assert "SHARDED-CUBE-MAJOR-OK" in out


# --------------------------------------------------------------------------
# Tuning subsystem (kernels/tune.py): table, resolution, the "auto" path
# --------------------------------------------------------------------------

def test_autotune_writes_table_and_resolves(tmp_path):
    path = str(tmp_path / "table.json")
    entry = tune.autotune(2, 3, n_n=20, reps=1, path=path)
    assert entry["layout"] in tune.LAYOUTS
    assert set(entry["seconds"]) == {
        v.key() for v in tune.default_variants(1, True)}
    with open(path) as f:
        table = json.load(f)
    assert table["version"] == tune.TABLE_VERSION
    assert tune.table_key(2, 3, entry["backend"]) in table["entries"]
    # exact hit
    v = tune.resolve_variant(2, 3, entry["backend"], path)
    assert dataclasses.astuple(v) == (
        entry["layout"], entry["block_words"], entry["r_tile"])
    # nearest-R fallback (same width+backend)
    assert tune.resolve_variant(2, 100, entry["backend"], path) == v
    # misses fall back to the conservative default
    assert tune.resolve_variant(9, 3, entry["backend"], path) \
        == tune.KernelVariant()
    assert tune.resolve_layout(2, 3, "some_other_backend", path) \
        == tune.DEFAULT_LAYOUT


def test_layout_auto_resolves_through_tuning_table(tmp_path, monkeypatch):
    """ops.cgp_eval_batched(layout="auto") dispatches the layout the tuning
    table picked for this (width, R, backend)."""
    path = str(tmp_path / "table.json")
    spec = CGPSpec(n_i=4, n_o=4, n_n=10)
    backend = tune.backend_key(True)  # interpret mode on this host
    tune.save_entry(2, 3, backend,
                    {"layout": "cube_major", "block_words": 1, "r_tile": 1},
                    path)
    monkeypatch.setenv(tune.TABLE_ENV, path)

    seen = []
    real = cgp_sim.cgp_sim_metrics_batched

    def recorder(*args, **kw):
        seen.append(kw.get("layout"))
        return real(*args, **kw)

    monkeypatch.setattr(cgp_sim, "cgp_sim_metrics_batched", recorder)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(2, "mul"))
    genomes = _stacked_genomes(spec, 3)
    ops.cgp_eval_batched(genomes, spec, planes, gvals, block_words=1)
    assert seen == ["cube_major"]

    # with no table behind the env var, "auto" falls back to genome-major
    monkeypatch.setenv(tune.TABLE_ENV, str(tmp_path / "absent.json"))
    seen.clear()
    ops.cgp_eval_batched(genomes, spec, planes, gvals, block_words=1)
    assert seen == ["genome_major"]

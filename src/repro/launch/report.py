"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
recorded JSON/CSV artifacts (re-run after any dryrun/roofline refresh):

    PYTHONPATH=src python -m repro.launch.report > experiments/report.md
"""
from __future__ import annotations

import csv
import glob
import json
import os

GB = 1e9


def dryrun_table(dryrun_dir: str = "experiments/dryrun") -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        d = json.load(open(path))
        mem = d.get("memory_analysis", {})
        cost = d.get("cost_analysis", {})
        colls = d.get("collectives", {})
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "devices": d.get("n_devices", ""),
            "ok": "✓" if d.get("ok") else "✗",
            "compile_s": d.get("compile_s", ""),
            "args_gb": round(mem.get("argument_size_in_bytes", 0) / GB, 2),
            "temp_gb": round(mem.get("temp_size_in_bytes", 0) / GB, 2),
            "flops_raw": f"{cost.get('flops', 0):.2e}",
            "coll_gb": round(colls.get("total_bytes", 0) / GB, 2),
            "coll_ops": "/".join(sorted(colls.get("per_op", {}))),
        })
    hdr = ("| arch | shape | mesh | devs | ok | compile s | args GB/dev | "
           "temp GB/dev | HLO flops (raw) | coll GB | collective ops |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['devices']} "
            f"| {r['ok']} | {r['compile_s']} | {r['args_gb']} "
            f"| {r['temp_gb']} | {r['flops_raw']} | {r['coll_gb']} "
            f"| {r['coll_ops']} |")
    return "\n".join(lines)


def roofline_table(csv_path: str = "experiments/roofline.csv") -> str:
    if not os.path.exists(csv_path):
        return "(roofline.csv not yet generated)"
    rows = list(csv.DictReader(open(csv_path)))
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful ratio | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if not r.get("compute_s"):
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        f = lambda k: f"{float(r[k]):.4g}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {f('compute_s')} "
            f"| {f('memory_s')} | {f('collective_s')} | {r['dominant']} "
            f"| {float(r['model_flops_total']):.3e} "
            f"| {f('useful_flops_ratio')} | {f('roofline_fraction')} |")
    return "\n".join(lines)


def main():
    print("## Dry-run matrix\n")
    print(dryrun_table())
    print("\n## Roofline table (single-pod, per-chip)\n")
    print(roofline_table())


if __name__ == "__main__":
    main()

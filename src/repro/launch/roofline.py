import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ same rule as dryrun.py: first lines, before any jax import.

"""Roofline analysis (task deliverable g).

Per (arch × shape) on the single-pod mesh, derive the three roofline terms:

    compute    = HLO_FLOPs_per_chip   / 197e12  (bf16 peak, v5e)
    memory     = HLO_bytes_per_chip   / 819e9   (HBM bandwidth)
    collective = coll_bytes_per_chip  / 50e9    (ICI per-link)

XLA:CPU's HloCostAnalysis counts `while` bodies ONCE, so raw numbers from
the full-depth compile undercount the layer scan.  We therefore use a
two-point calibration: compile the same cell at depth p and 2p layer-periods,

    body  = f(2p) - f(p)          (one period's contribution)
    base  = f(p)  - body          (embed + loss + outside-scan work)
    total = base + body·n_periods

which is exact for everything inside the (linear) scan.  The same scheme
corrects the collective-byte parse (raw per-module sums, no name
heuristics).  MODEL_FLOPS = 6·N(active)·tokens is computed analytically per
cell; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat recompute + causal-
attention waste, as required.

Outputs experiments/roofline.csv + a markdown table for EXPERIMENTS.md.
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.configs import base as B
from repro.launch import steps as ST
from repro.launch.dryrun import build_cell, parse_collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.parallel import ctx

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link


def model_flops(cfg: B.ModelConfig, shape: B.ShapeConfig) -> float:
    """Analytic MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)
    where D = processed tokens.  Embedding params excluded (lookup)."""
    n_active = active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1  # one decode step
    return 2.0 * n_active * tokens


def active_params(cfg: B.ModelConfig) -> float:
    """Non-embedding parameters touched per token."""
    d, hd = cfg.d_model, cfg.hd
    n = 0.0
    for spec in cfg.period:
        if spec.kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * d
            H = d_in // s.headdim
            n += d * (2 * d_in + 2 * s.n_groups * s.d_state + H)
            n += d_in * d  # out proj
        else:
            n += d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
            n += cfg.n_heads * hd * d
        if spec.has_ffn:
            if spec.moe:
                m = cfg.moe
                n += d * m.n_experts  # router
                n += m.top_k * 3 * d * m.d_ff_expert
            else:
                mult = 3 if cfg.act == "swiglu" else 2
                n += mult * d * cfg.d_ff
    n *= cfg.n_periods
    # lm head matmul participates in compute
    n += d * cfg.vocab * (cfg.n_codebooks if cfg.frontend == "audio" else 1)
    return n


def _measure(arch_id: str, shape: B.ShapeConfig, n_periods: int) -> dict:
    """Lower+compile the cell at a reduced period count; raw per-module
    sums (no trip multiplication)."""
    mod = B.get_arch(arch_id)
    cfg: B.ModelConfig = mod.CONFIG
    p_len = len(cfg.period)
    # UNROLLED (scan_layers=False): XLA cost analysis counts while bodies
    # once, so depth variation under a scan measures nothing — unrolled
    # variants count the full per-layer work.
    cfg_small = dataclasses.replace(cfg, n_layers=n_periods * p_len,
                                    scan_layers=False)
    # monkey-patch the arch module CONFIG so build_cell sees the variant
    old = mod.CONFIG
    mod.CONFIG = cfg_small
    try:
        fn, args, in_sh, out_sh, donate, _ = build_cell(arch_id, shape)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        compiled = jitted.lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        colls = parse_collective_bytes(compiled.as_text(), {"default": 1})
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0)),
                "coll": colls["total_bytes"],
                "per_op": colls["per_op"]}
    finally:
        mod.CONFIG = old


def analyze_cell(arch_id: str, shape: B.ShapeConfig) -> dict:
    mesh = make_production_mesh(multi_pod=False)
    cfg = B.get_arch(arch_id).CONFIG
    with ctx.use_mesh(mesh):
        f1 = _measure(arch_id, shape, 1)
        f2 = _measure(arch_id, shape, 2)
    body = {k: f2[k] - f1[k] for k in ("flops", "bytes", "coll")}
    base = {k: f1[k] - body[k] for k in ("flops", "bytes", "coll")}
    total = {k: max(0.0, base[k] + body[k] * cfg.n_periods)
             for k in ("flops", "bytes", "coll")}
    mf = model_flops(cfg, shape)
    chips = mesh.size
    compute_s = total["flops"] / PEAK_FLOPS
    memory_s = total["bytes"] / HBM_BW
    coll_s = total["coll"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    # useful-compute fraction: analytic model flops per chip vs HLO flops
    ratio = (mf / chips) / max(total["flops"], 1e-9)
    roofline_fraction = (mf / chips / PEAK_FLOPS) / max(bound_s, 1e-12)
    return {
        "arch": arch_id, "shape": shape.name, "chips": chips,
        "hlo_flops_per_chip": total["flops"],
        "hlo_bytes_per_chip": total["bytes"],
        "coll_bytes_per_chip": total["coll"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_total": mf,
        "useful_flops_ratio": ratio,
        "roofline_fraction": roofline_fraction,
        "per_op_p2": f2["per_op"],
    }


NOTES = {
    "compute": ("dominant term is compute: reduce recompute (remat policy), "
                "skip fully-masked causal KV blocks, or use more chips"),
    "memory": ("dominant term is HBM: fuse/chunk the loss, cut activation "
               "round-trips, shard the weak dim, or quantize weights"),
    "collective": ("dominant term is ICI: reshard to cut all-gathers, "
                   "overlap collectives with compute (microbatch scan), "
                   "or compress gradients"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/roofline.csv")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in B.ARCH_IDS:
            for shape in B.shapes_for(arch):
                cells.append((arch, shape))
    else:
        shape = {s.name: s for s in B.ALL_SHAPES}[args.shape]
        cells.append((args.arch, shape))

    rows = []
    for arch, shape in cells:
        try:
            r = analyze_cell(arch, shape)
        except Exception as e:
            r = {"arch": arch, "shape": shape.name, "error": str(e)[:200]}
        rows.append(r)
        print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                          for k, v in r.items() if k != "per_op_p2"}),
              flush=True)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    cols = ["arch", "shape", "chips", "hlo_flops_per_chip",
            "hlo_bytes_per_chip", "coll_bytes_per_chip", "compute_s",
            "memory_s", "collective_s", "dominant", "model_flops_total",
            "useful_flops_ratio", "roofline_fraction"]
    with open(args.out, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(c, "")) for c in cols) + "\n")
    with open(args.out.replace(".csv", "_notes.json"), "w") as f:
        json.dump([{**{k: v for k, v in r.items() if k != "per_op_p2"},
                    "note": NOTES.get(r.get("dominant", ""), "")}
                   for r in rows], f, indent=1)
    print(f"[roofline] wrote {args.out}")


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
#   init).  Set ONLY here — tests/benchmarks must see the real single device.

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on the production mesh and record memory/cost/collective evidence.

For every cell this lowers the SAME step functions the launchers run
(launch/steps.py): train_4k -> train_step (grads + optimizer), prefill_32k ->
prefill, decode_32k/long_500k -> decode_step.  ``.lower().compile()``
succeeding proves the sharding config is coherent; the JSON output feeds
launch/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --cgp --mesh multi     # the paper's workload
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import base as B
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models.model import param_specs as model_param_specs
from repro.optim import OptConfig, opt_state_specs
from repro.parallel import ctx

# opcode must be immediately followed by '(' — otherwise operand NAMES like
# `copy(%all-gather)` would be counted as collectives (double counting)
COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|u32|s8|u8|s16|u16|pred|s64|u64)"
                      r"\[([0-9,]*)\]")
DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "s64": 8,
               "u64": 8}


def parse_collective_bytes(hlo: str, loop_trip_counts: dict[str, int]
                           ) -> dict:
    """Sum output bytes of every collective op in the (post-SPMD) HLO.

    Ops inside `while` bodies are multiplied by the known layer-scan trip
    count (``loop_trip_counts['default']``); the computation→while nesting is
    detected from the fusion/computation names (documented calibration — see
    EXPERIMENTS.md §Roofline).
    """
    per_op: dict[str, float] = {}
    total = 0.0
    current_comp = ""
    body_mult = 1.0
    for line in hlo.splitlines():
        line_s = line.strip()
        if line_s.startswith(("%", "ENTRY")) and "{" in line_s and "=" not in line_s.split("{")[0]:
            current_comp = line_s.split(" ")[0].lstrip("%")
            body_mult = (loop_trip_counts.get("default", 1)
                         if ("while" in current_comp or
                             "body" in current_comp or
                             "scan" in current_comp) else 1.0)
            continue
        m = COLLECTIVE_RE.search(line_s)
        if not m or "=" not in line_s:
            continue
        if m.group(2) == "-done":
            continue  # async pair: count the -start only
        # bytes of the op RESULT: shape(s) between '=' and the opcode
        eq = line_s.index("=")
        shapes = SHAPE_RE.findall(line_s[eq:m.start()])
        nbytes = 0.0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        kind = m.group(1)
        per_op[kind] = per_op.get(kind, 0.0) + nbytes * body_mult
        total += nbytes * body_mult
    return {"total_bytes": total, "per_op": per_op}


def build_cell(arch_id: str, shape: B.ShapeConfig):
    """(step_fn, in_shardings tree, abstract args) for one cell."""
    mod = B.get_arch(arch_id)
    cfg: B.ModelConfig = mod.CONFIG
    opt_cfg = OptConfig(name=getattr(mod, "OPTIMIZER", "adamw"))
    batch_sds = B.input_specs(cfg, shape)
    batch_specs = ST.batch_specs(cfg, shape)
    params_sds = ST.abstract_params(cfg)
    pspecs = ST.resolve_tree(model_param_specs(cfg))
    if shape.mode == "train":
        step = ST.make_train_step(cfg, opt_cfg)
        opt_sds = ST.abstract_opt_state(cfg, opt_cfg)
        ospecs = ST.resolve_tree(opt_state_specs(model_param_specs(cfg),
                                                 opt_cfg))
        bshard = ST.resolve_tree(batch_specs)
        args = (params_sds, opt_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (pspecs, ospecs, bshard, None)
        out_sh = (pspecs, ospecs, None)
        donate = (0, 1)
        fn = step
    elif shape.mode == "prefill":
        fn = ST.make_prefill_step(cfg)
        cache_specs = ST.resolve_tree(
            ST.stacked_cache_specs(cfg, shape.global_batch))
        args = (params_sds, batch_sds)
        in_sh = (pspecs, ST.resolve_tree(batch_specs))
        out_sh = (None, cache_specs)
        donate = ()
    else:  # decode
        seq_shard = shape.global_batch < ctx.axis_size("dp")
        fn = ST.make_decode_step(cfg, seq_shard=seq_shard)
        cache_sds = ST.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cache_specs = ST.resolve_tree(
            ST.stacked_cache_specs(cfg, shape.global_batch))
        args = (params_sds, cache_sds, batch_sds)
        in_sh = (pspecs, cache_specs, ST.resolve_tree(batch_specs))
        out_sh = (None, cache_specs)
        donate = (1,)
    return fn, args, in_sh, out_sh, donate, cfg


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None) -> dict:
    shape = {s.name: s for s in B.ALL_SHAPES}[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "n_devices": mesh.size}
    try:
        with ctx.use_mesh(mesh):
            fn, args, in_sh, out_sh, donate, cfg = build_cell(arch_id, shape)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = {}
            try:
                ma = compiled.memory_analysis()
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                    mem[k] = int(getattr(ma, k, 0) or 0)
            except Exception as e:  # backend-dependent
                mem["error"] = str(e)
            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                cost = {k: float(v) for k, v in ca.items()
                        if isinstance(v, (int, float)) and (
                            "flops" in k or "bytes" in k or
                            "utilization" not in k)}
            except Exception as e:
                cost["error"] = str(e)
            hlo = compiled.as_text()
            colls = parse_collective_bytes(
                hlo, {"default": cfg.n_periods})
            rec.update({
                "ok": True, "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory_analysis": mem, "cost_analysis": cost,
                "collectives": colls,
                "n_periods": cfg.n_periods,
                "hlo_bytes": len(hlo),
            })
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_id}__{shape_name}__{rec['mesh']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def run_cgp_cell(multi_pod: bool, out_dir: str | None = None) -> dict:
    """Dry-run the paper's own workload: the distributed CGP evolve step."""
    import numpy as np
    from repro.core import golden as G
    from repro.core import metrics as MM
    from repro.core.evolve import EvolveConfig, evolve_sharded
    from repro.core.genome import CGPSpec
    from repro.core.search import SearchConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": "cgp_mult8", "shape": "evolve_64g",
           "mesh": "multi" if multi_pod else "single",
           "n_devices": mesh.size}
    t0 = time.time()
    try:
        gold, spec = G.array_multiplier(8, n_n=400)
        n_pods = mesh.shape.get("pod", 1)
        n_islands = mesh.shape["data"] * n_pods
        n_model = mesh.shape["model"]
        cfg = EvolveConfig(generations=64, lam=8)
        thr = jax.ShapeDtypeStruct((n_pods, MM.N_METRICS), jnp.float32)
        keys = jax.ShapeDtypeStruct((n_islands, 2), jnp.uint32)
        W = spec.n_words
        planes = jax.ShapeDtypeStruct((spec.n_i, W), jnp.int32)
        gvals = jax.ShapeDtypeStruct((W * 32,), jnp.int32)
        with ctx.use_mesh(mesh):
            fn = evolve_sharded(
                mesh, spec, cfg, gold,
                thresholds_per_pod=thr, golden_power=jnp.float32(100.0),
                pod_axis="pod" if multi_pod else None)
            jitted = jax.jit(lambda t, k, p, g: fn(t, k, p, g))
            lowered = jitted.lower(thr, keys, planes, gvals)
            compiled = lowered.compile()
            cost = {}
            try:
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):
                    ca = ca[0]
                cost = {k: float(v) for k, v in ca.items()
                        if isinstance(v, (int, float))}
            except Exception as e:
                cost["error"] = str(e)
            mem = {}
            try:
                ma = compiled.memory_analysis()
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes"):
                    mem[k] = int(getattr(ma, k, 0) or 0)
            except Exception as e:
                mem["error"] = str(e)
            hlo = compiled.as_text()
            rec.update({
                "ok": True, "compile_s": round(time.time() - t0, 2),
                "cost_analysis": cost, "memory_analysis": mem,
                "collectives": parse_collective_bytes(
                    hlo, {"default": cfg.generations}),
                "hlo_bytes": len(hlo)})
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"cgp_mult8__evolve__{rec['mesh']}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cgp", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.cgp:
        for mp in meshes:
            rec = run_cgp_cell(mp, args.out)
            print(json.dumps({k: rec[k] for k in
                              ("arch", "shape", "mesh", "ok")}),
                  flush=True)
        return
    if args.all:
        for arch in B.ARCH_IDS:
            for shape in B.shapes_for(arch):
                cells.append((arch, shape.name))
    else:
        cells.append((args.arch, args.shape))
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out)
            brief = {k: rec.get(k) for k in
                     ("arch", "shape", "mesh", "ok", "compile_s", "error")}
            print(json.dumps(brief), flush=True)


if __name__ == "__main__":
    main()

"""Artifact-registry export CLI (the evolve → LUT → serve bridge, DESIGN.md
§12).

Export per-constraint elite circuits from a sweep results directory as
fingerprinted LUT artifacts:

  PYTHONPATH=src python -m repro.launch.export \
      --results-dir /shared/sweep-shards --out /shared/registry --top-k 1

Verify an existing registry (digests + genome→LUT replay; what the CI
``deploy`` leg runs before serving anything):

  PYTHONPATH=src python -m repro.launch.export --verify /shared/registry
"""
from __future__ import annotations

import argparse
import sys

from repro.core import artifacts as A


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Export sweep elites as fingerprinted LUT artifacts "
                    "(core.artifacts), or verify an existing registry.")
    ap.add_argument("--results-dir",
                    help="sweep shard directory (core.results) to export "
                         "elites from")
    ap.add_argument("--out",
                    help="registry directory to write artifacts + "
                         "registry.json into")
    ap.add_argument("--top-k", type=int, default=1,
                    help="artifacts per constraint group (default: 1)")
    ap.add_argument("--include-infeasible", action="store_true",
                    help="also export constraint-violating elites "
                         "(default: feasible rows only)")
    ap.add_argument("--require-certified", action="store_true",
                    help="only export rows whose metrics are exact-"
                         "certified (DESIGN.md section 10)")
    ap.add_argument("--width", type=int, default=None,
                    help="operand bit width override for results "
                         "directories whose manifest predates problem "
                         "metadata")
    ap.add_argument("--kind", default=None, choices=["mul", "add"],
                    help="circuit kind override (only 'mul' is exportable)")
    ap.add_argument("--verify", metavar="REGISTRY_DIR",
                    help="verify every artifact in an existing registry "
                         "instead of exporting (digest + genome replay + "
                         "fingerprint pinning)")
    args = ap.parse_args(argv)

    if args.verify:
        arts = A.verify_registry(args.verify)
        for art in arts:
            print(f"[export] OK {art.path}: {art.constraint} seed "
                  f"{art.seed} power_rel={art.power_rel:.4f} "
                  f"certified={art.certified} digest {art.digest[:12]}…")
        print(f"[export] registry {args.verify}: {len(arts)} artifact(s) "
              f"verified")
        return 0

    if not args.results_dir or not args.out:
        ap.error("--results-dir and --out are required (or use --verify)")
    policy = A.ExportPolicy(top_k=args.top_k,
                            feasible_only=not args.include_infeasible,
                            require_certified=args.require_certified)
    registry = A.export_elites(args.results_dir, args.out, policy,
                               width=args.width, kind=args.kind)
    for e in registry["artifacts"]:
        print(f"[export] {e['file']}: {e['constraint']} seed {e['seed']} "
              f"power_rel={e['power_rel']:.4f} certified={e['certified']}")
    print(f"[export] {len(registry['artifacts'])} artifact(s) -> "
          f"{args.out} (grid {registry['grid_fingerprint'][:12]}…)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

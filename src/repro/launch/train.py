"""Training launcher: fault-tolerant LM training on any mesh.

Wires together the substrate: deterministic data pipeline, optimizer,
step-granular async checkpoints with auto-resume, heartbeat/straggler guard,
and the sharded train step from launch/steps.py (identical to what the
dry-run lowers).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import base as B
from repro.data.pipeline import DataConfig, synth_batch
from repro.launch import steps as ST
from repro.launch.mesh import make_debug_mesh, make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state, opt_state_specs
from repro.parallel import ctx
from repro.runtime.fault import HeartbeatMonitor, StragglerDetector, TrainGuard


def build_mesh(name: str):
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    if name == "host":
        return make_host_mesh()
    if name == "none":
        return None
    raise ValueError(name)


def train(arch: str, steps: int = 50, batch: int | None = None,
          seq: int | None = None, reduced: bool = True,
          mesh_name: str = "none", ckpt_dir: str | None = None,
          ckpt_every: int = 20, microbatches: int = 1,
          opt_name: str | None = None, seed: int = 0,
          log_every: int = 10) -> dict:
    mod = B.get_arch(arch)
    cfg: B.ModelConfig = mod.reduced() if reduced else mod.CONFIG
    opt_cfg = OptConfig(name=opt_name or getattr(mod, "OPTIMIZER", "adamw"),
                        total_steps=max(steps, 2))
    batch = batch or (8 if reduced else B.TRAIN_4K.global_batch)
    seq = seq or (64 if reduced else B.TRAIN_4K.seq_len)
    mesh = build_mesh(mesh_name)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                      seed=seed, n_codebooks=cfg.n_codebooks)

    with (ctx.use_mesh(mesh) if mesh is not None
          else _null_ctx()):
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        opt_state = init_opt_state(params, opt_cfg)
        if mesh is not None:
            pspecs = ST.resolve_tree(M.param_specs(cfg))
            ospecs = ST.resolve_tree(
                opt_state_specs(M.param_specs(cfg), opt_cfg))
            params = jax.device_put(params, pspecs)
            opt_state = jax.device_put(opt_state, ospecs)

        start_step = 0
        ckpt = store.AsyncCheckpointer()
        if ckpt_dir:
            latest = store.latest_step(ckpt_dir)
            if latest is not None:
                # fault-tolerant resume: restore onto the CURRENT mesh
                # (elastic — the checkpoint may come from another topology)
                tmpl = {"params": params, "opt": opt_state}
                shardings = None
                if mesh is not None:
                    shardings = {"params": pspecs, "opt": ospecs}
                tree, meta = store.load_checkpoint(
                    ckpt_dir, latest, tmpl, shardings)
                params, opt_state = tree["params"], tree["opt"]
                start_step = latest
                print(f"[train] resumed from step {latest}", flush=True)

        step_fn = ST.make_train_step(cfg, opt_cfg, microbatches=microbatches)
        jit_kwargs = {}
        if mesh is not None:
            jit_kwargs = dict(
                in_shardings=(pspecs, ospecs,
                              ST.resolve_tree(
                                  ST.batch_specs(cfg, B.ShapeConfig(
                                      "t", seq, batch, "train"))), None),
                out_shardings=(pspecs, ospecs, None),
            )
        jstep = jax.jit(step_fn, donate_argnums=(0, 1), **jit_kwargs)

        guard = TrainGuard(HeartbeatMonitor(deadline_s=300.0),
                           StragglerDetector())
        host = f"host{jax.process_index()}"
        losses = []
        t_start = time.time()
        for s in range(start_step, steps):
            t0 = time.time()
            npb = synth_batch(dcfg, s)
            jb = {k: jnp.asarray(v) for k, v in npb.items()}
            if cfg.frontend == "vision":
                jb["image_embeds"] = jnp.zeros(
                    (batch, cfg.n_img_tokens, cfg.d_model), cfg.adtype())
            params, opt_state, metrics = jstep(params, opt_state, jb,
                                               jnp.int32(s))
            loss = float(metrics["loss"])
            losses.append(loss)
            status = guard.step(host, time.time() - t0)
            if status["stragglers"]:
                print(f"[guard] stragglers: {status['stragglers']}",
                      flush=True)
            if ckpt_dir and (s + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, s + 1,
                          {"params": params, "opt": opt_state},
                          metadata={"arch": arch, "loss": loss})
            if s % log_every == 0 or s == steps - 1:
                print(f"[train] step {s} loss {loss:.4f} "
                      f"({time.time() - t0:.2f}s)", flush=True)
        ckpt.wait()
        if ckpt_dir:
            store.save_checkpoint(ckpt_dir, steps,
                                  {"params": params, "opt": opt_state},
                                  metadata={"arch": arch,
                                            "loss": losses[-1]})
            store.cleanup(ckpt_dir, keep=3)
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "steps": steps, "wall_s": time.time() - t_start}


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single", "multi"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                reduced=args.reduced, mesh_name=args.mesh,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                microbatches=args.microbatches, opt_name=args.optimizer,
                seed=args.seed)
    print(f"[train] done: final loss {out['final_loss']:.4f} "
          f"in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()

"""Serving launcher: batched prefill + decode with continuous batching.

A minimal production-shaped server loop: requests arrive in a queue, are
admitted into fixed decode slots (continuous batching), prefilled, then
decoded step-by-step; finished slots are immediately refilled.  The decode
step is the same function the dry-run lowers for decode_32k/long_500k.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced \
      --requests 8 --prompt-len 32 --gen-len 16

Approximate-arithmetic serving (the evolve → LUT → serve bridge, DESIGN.md
§12): ``--approx-lut`` takes a verified registry artifact (or a registry
directory — the lowest-power feasible entry is picked) and routes every
projection matmul through the evolved multiplier's product LUT
(``models/quant.approx_matmul`` → ``kernels/lut_matmul``), then reports
requests/s, tokens/s and the model-level damage — logit error and
perplexity delta vs exact-int8 and vs fp32.  ``--summary-out`` lands the
whole report as a stamped ``deploy_summary.json``:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced \
      --requests 4 --approx-lut /shared/registry --summary-out \
      deploy_summary.json
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as B
from repro.launch import steps as ST
from repro.models import model as M
from repro.models import quant
from repro.parallel import ctx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) or (S, C) token ids
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def serve(arch: str, n_requests: int = 8, prompt_len: int = 32,
          gen_len: int = 16, slots: int = 4, reduced: bool = True,
          seed: int = 0, greedy: bool = True,
          approx_lut: np.ndarray | None = None) -> dict:
    """Run the continuous-batching loop; returns throughput + outputs.

    ``approx_lut`` (a 256×256 int32 product table) routes every projection
    matmul through the emulated approximate multiplier for the whole run.
    The LUT is installed BEFORE the jit closures below are built — the
    ``models/quant`` module global is captured as a compile-time constant,
    so a fresh ``serve`` call per LUT is the supported pattern (this
    function builds fresh closures every call) — and the previous LUT is
    restored on exit.
    """
    mod = B.get_arch(arch)
    cfg: B.ModelConfig = mod.reduced() if reduced else mod.CONFIG
    prev_lut = quant._LUT
    if approx_lut is not None:
        if tuple(np.shape(approx_lut)) != (256, 256):
            raise ValueError(
                f"approx_lut must be a 256x256 product table (8-bit "
                f"operands), got {np.shape(approx_lut)} — re-export from a "
                f"width-8 sweep")
        cfg = dataclasses.replace(cfg, approx_matmul=True)
        quant.set_multiplier_lut(approx_lut)
    try:
        return _serve_loop(cfg, n_requests, prompt_len, gen_len, slots,
                           seed)
    finally:
        quant._LUT = prev_lut


def _serve_loop(cfg: B.ModelConfig, n_requests: int, prompt_len: int,
                gen_len: int, slots: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    max_len = prompt_len + gen_len

    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    prefill_fn = jax.jit(lambda p, t, img: M.prefill(
        p, t, cfg, max_len=max_len, image_embeds=img))
    decode_fn = jax.jit(lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    tok_shape = ((prompt_len, cfg.n_codebooks) if cfg.frontend == "audio"
                 else (prompt_len,))
    reqs = [Request(i, rng.integers(0, cfg.vocab, tok_shape,
                                    dtype=np.int32), gen_len)
            for i in range(n_requests)]
    pending = list(reqs)
    t0 = time.time()
    decoded_tokens = 0

    img = (jnp.zeros((slots, cfg.n_img_tokens, cfg.d_model), cfg.adtype())
           if cfg.frontend == "vision" else None)

    while pending or any(not r.done for r in reqs):
        batch_reqs = [r for r in pending[:slots]]
        pending = pending[len(batch_reqs):]
        if not batch_reqs:
            break
        while len(batch_reqs) < slots:          # pad the slot batch
            batch_reqs.append(batch_reqs[-1])
        prompts = jnp.asarray(np.stack([r.prompt for r in batch_reqs]))
        logits, cache = prefill_fn(params, prompts, img)
        pos = jnp.full((slots,), prompt_len, jnp.int32)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(gen_len):
            tok_in = (next_tok[:, None] if cfg.frontend != "audio"
                      else next_tok[:, None])
            logits, cache = decode_fn(params, cache, tok_in, pos)
            next_np = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i, r in enumerate(batch_reqs):
                if not r.done and len(r.out) < r.max_new:
                    r.out.append(int(np.atleast_1d(next_np[i]).ravel()[0]))
                    decoded_tokens += 1
                if len(r.out) >= r.max_new:
                    r.done = True
            next_tok = jnp.asarray(
                np.atleast_2d(next_np).reshape(slots, -1)[:, 0],
                dtype=jnp.int32) if cfg.frontend != "audio" else jnp.asarray(
                np.atleast_2d(next_np).reshape(slots, -1), dtype=jnp.int32)
            pos = pos + 1
    wall = time.time() - t0
    return {"requests": n_requests, "decoded_tokens": decoded_tokens,
            "wall_s": wall, "tok_per_s": decoded_tokens / max(wall, 1e-9),
            "req_per_s": n_requests / max(wall, 1e-9),
            "outputs": {r.rid: r.out for r in reqs}}


def quality_report(arch: str, lut: np.ndarray, *, reduced: bool = True,
                   batch: int = 4, seq_len: int = 32, seed: int = 0) -> dict:
    """Model-level damage of serving on the evolved multiplier.

    Evaluates the SAME random params + token batch under three arithmetics —
    fp32, exact-int8 (quantization alone) and the approximate LUT — and
    reports perplexities, their deltas, and mean-|Δlogit| of the prefill
    logits vs each baseline.  All eager: every call reads the freshly
    installed LUT (no jit-constant staleness).
    """
    mod = B.get_arch(arch)
    cfg: B.ModelConfig = mod.reduced() if reduced else mod.CONFIG
    cfg_q = dataclasses.replace(cfg, approx_matmul=True)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    tok_shape = ((batch, seq_len, cfg.n_codebooks)
                 if cfg.frontend == "audio" else (batch, seq_len))
    toks = jax.random.randint(key, tok_shape, 0, cfg.vocab)
    img = (jnp.zeros((batch, cfg.n_img_tokens, cfg.d_model), cfg.adtype())
           if cfg.frontend == "vision" else None)

    prev_lut = quant._LUT
    try:
        def run(c):
            loss = float(M.lm_loss(params, toks, toks, c,
                                   image_embeds=img))
            logits, _ = M.prefill(params, toks, c, image_embeds=img)
            return loss, np.asarray(logits, np.float32)

        loss_fp, logits_fp = run(cfg)
        quant.set_multiplier_lut(None)          # exact-int8 baseline
        loss_i8, logits_i8 = run(cfg_q)
        quant.set_multiplier_lut(lut)           # evolved approximate circuit
        loss_ap, logits_ap = run(cfg_q)
    finally:
        quant._LUT = prev_lut

    ppl_fp, ppl_i8, ppl_ap = (float(np.exp(v))
                              for v in (loss_fp, loss_i8, loss_ap))
    return {
        "ppl_fp32": ppl_fp, "ppl_int8": ppl_i8, "ppl_approx": ppl_ap,
        "ppl_delta_vs_fp32": ppl_ap - ppl_fp,
        "ppl_delta_vs_int8": ppl_ap - ppl_i8,
        "logit_mae_vs_fp32": float(np.abs(logits_ap - logits_fp).mean()),
        "logit_mae_vs_int8": float(np.abs(logits_ap - logits_i8).mean()),
        "eval_batch": batch, "eval_seq_len": seq_len,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--approx-lut", default=None, metavar="ARTIFACT",
                    help="serve on an evolved approximate multiplier: a "
                         "registry artifact .npz, or a registry directory "
                         "(lowest-power feasible entry wins).  The artifact "
                         "is digest-verified and its LUT replayed from the "
                         "genome before anything is served (core.artifacts, "
                         "DESIGN.md section 12); quality deltas vs "
                         "exact-int8 and fp32 are reported next to "
                         "throughput")
    ap.add_argument("--summary-out", default=None, metavar="PATH",
                    help="write the run's throughput + quality report as a "
                         "stamped deploy_summary.json (atomic write)")
    args = ap.parse_args(argv)

    art = None
    lut = None
    if args.approx_lut:
        from repro.core.artifacts import resolve_artifact
        art = resolve_artifact(args.approx_lut)  # digest + genome verified
        lut = art.lut
        print(f"[serve] approx artifact {art.path}: {art.constraint} "
              f"(seed {art.seed}, power_rel={art.power_rel:.4f}, "
              f"certified={art.certified}, digest {art.digest[:12]}...)")

    out = serve(args.arch, n_requests=args.requests,
                prompt_len=args.prompt_len, gen_len=args.gen_len,
                slots=args.slots, reduced=args.reduced, approx_lut=lut)
    print(f"[serve] {out['requests']} requests, "
          f"{out['decoded_tokens']} tokens, {out['tok_per_s']:.1f} tok/s, "
          f"{out['req_per_s']:.2f} req/s")

    quality = None
    if lut is not None:
        quality = quality_report(args.arch, lut, reduced=args.reduced,
                                 seq_len=args.prompt_len)
        print(f"[serve] perplexity fp32 {quality['ppl_fp32']:.4f} | "
              f"exact-int8 {quality['ppl_int8']:.4f} | "
              f"approx {quality['ppl_approx']:.4f} "
              f"(delta vs int8 {quality['ppl_delta_vs_int8']:+.4f}, "
              f"vs fp32 {quality['ppl_delta_vs_fp32']:+.4f})")
        print(f"[serve] logit MAE vs int8 "
              f"{quality['logit_mae_vs_int8']:.4f}, vs fp32 "
              f"{quality['logit_mae_vs_fp32']:.4f}")

    if args.summary_out:
        from repro.checkpoint.store import atomic_write_json
        summary = {
            "schema_version": 1,
            "generated_unix": time.time(),
            "arch": args.arch, "reduced": args.reduced,
            "budget": {"requests": args.requests,
                       "prompt_len": args.prompt_len,
                       "gen_len": args.gen_len, "slots": args.slots},
            "artifact": None if art is None else {
                "path": art.path, "digest": art.digest,
                "grid_fingerprint": art.grid_fingerprint,
                "constraint": art.constraint, "seed": art.seed,
                "power_rel": art.power_rel, "feasible": art.feasible,
                "certified": art.certified,
                "metrics": art.metric_dict(),
            },
            "serve": {k: v for k, v in out.items() if k != "outputs"},
            "quality": quality,
        }
        atomic_write_json(args.summary_out, summary)
        print(f"[serve] wrote {args.summary_out}")


if __name__ == "__main__":
    main()

"""Serving launcher: batched prefill + decode with continuous batching.

A minimal production-shaped server loop: requests arrive in a queue, are
admitted into fixed decode slots (continuous batching), prefilled, then
decoded step-by-step; finished slots are immediately refilled.  The decode
step is the same function the dry-run lowers for decode_32k/long_500k.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_2_1b --reduced \
      --requests 8 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as B
from repro.launch import steps as ST
from repro.models import model as M
from repro.parallel import ctx


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) or (S, C) token ids
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def serve(arch: str, n_requests: int = 8, prompt_len: int = 32,
          gen_len: int = 16, slots: int = 4, reduced: bool = True,
          seed: int = 0, greedy: bool = True) -> dict:
    mod = B.get_arch(arch)
    cfg: B.ModelConfig = mod.reduced() if reduced else mod.CONFIG
    rng = np.random.default_rng(seed)
    max_len = prompt_len + gen_len

    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    prefill_fn = jax.jit(lambda p, t, img: M.prefill(
        p, t, cfg, max_len=max_len, image_embeds=img))
    decode_fn = jax.jit(lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    tok_shape = ((prompt_len, cfg.n_codebooks) if cfg.frontend == "audio"
                 else (prompt_len,))
    reqs = [Request(i, rng.integers(0, cfg.vocab, tok_shape,
                                    dtype=np.int32), gen_len)
            for i in range(n_requests)]
    pending = list(reqs)
    t0 = time.time()
    decoded_tokens = 0

    img = (jnp.zeros((slots, cfg.n_img_tokens, cfg.d_model), cfg.adtype())
           if cfg.frontend == "vision" else None)

    while pending or any(not r.done for r in reqs):
        batch_reqs = [r for r in pending[:slots]]
        pending = pending[len(batch_reqs):]
        if not batch_reqs:
            break
        while len(batch_reqs) < slots:          # pad the slot batch
            batch_reqs.append(batch_reqs[-1])
        prompts = jnp.asarray(np.stack([r.prompt for r in batch_reqs]))
        logits, cache = prefill_fn(params, prompts, img)
        pos = jnp.full((slots,), prompt_len, jnp.int32)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(gen_len):
            tok_in = (next_tok[:, None] if cfg.frontend != "audio"
                      else next_tok[:, None])
            logits, cache = decode_fn(params, cache, tok_in, pos)
            next_np = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for i, r in enumerate(batch_reqs):
                if not r.done and len(r.out) < r.max_new:
                    r.out.append(int(np.atleast_1d(next_np[i]).ravel()[0]))
                    decoded_tokens += 1
                if len(r.out) >= r.max_new:
                    r.done = True
            next_tok = jnp.asarray(
                np.atleast_2d(next_np).reshape(slots, -1)[:, 0],
                dtype=jnp.int32) if cfg.frontend != "audio" else jnp.asarray(
                np.atleast_2d(next_np).reshape(slots, -1), dtype=jnp.int32)
            pos = pos + 1
    wall = time.time() - t0
    return {"requests": n_requests, "decoded_tokens": decoded_tokens,
            "wall_s": wall, "tok_per_s": decoded_tokens / max(wall, 1e-9),
            "outputs": {r.rid: r.out for r in reqs}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()
    out = serve(args.arch, n_requests=args.requests,
                prompt_len=args.prompt_len, gen_len=args.gen_len,
                slots=args.slots, reduced=args.reduced)
    print(f"[serve] {out['requests']} requests, "
          f"{out['decoded_tokens']} tokens, {out['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()

"""Production mesh construction (task-brief interface, verbatim semantics).

A FUNCTION (not module-level constant) so importing never touches jax device
state.  Single pod: (16, 16) = 256 chips (data, model).  Multi-pod:
(2, 16, 16) = 512 chips (pod, data, model) — the pod axis carries
data-parallel replication across pods for LM cells and the
constraint-configuration sweep for CGP cells (DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_host_mesh():
    """Whatever devices exist, as a 1×N (data, model) mesh (examples/CI)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"))

"""Production mesh construction (task-brief interface, verbatim semantics).

Every builder is a FUNCTION (not a module-level constant) so importing this
module never touches jax device state.  Axis semantics (DESIGN.md §2.2/§5):

  * ``pod``   — data-parallel replication for LM cells; the constraint-grid
    partition of the pod-sharded sweep for CGP cells (DESIGN.md §6 — pods
    run disjoint chunk slices, no cross-pod collectives).
  * ``data``  — batch parallelism (LM) / evolution islands (CGP).
  * ``model`` — tensor parallelism (LM) / input-cube sharding (CGP: metric
    partials psum across it).

The logical names model code uses resolve against these physical axes in
``parallel.ctx.LOGICAL``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The task-brief production topology.

    Single pod: ``(16, 16)`` = 256 chips (data, model).  Multi-pod:
    ``(2, 16, 16)`` = 512 chips (pod, data, model) — the pod axis carries
    data-parallel replication across pods for LM cells and the
    constraint-configuration sweep partition for CGP cells.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small fixed-shape mesh for tests.

    Needs ``n_data * n_model`` (× ``pods`` if nonzero) devices — tests get
    them by forcing ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    in a subprocess (see ``tests/conftest.run_subprocess``).  ``pods=0``
    omits the pod axis entirely (the single-pod production shape in
    miniature); ``pods>=1`` prepends it.
    """
    if pods:
        return jax.make_mesh((pods, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_host_mesh():
    """Whatever devices exist, as a 1×N (data, model) mesh (examples/CI)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"))


def make_sweep_mesh(pods: int = 1):
    """All local devices as a (pod, data, model) mesh for the pod-sharded
    sweep (DESIGN.md §6): ``pods`` slices of the constraint grid, the rest
    of the devices on the ``model`` axis for input-cube sharding
    (``SweepConfig.model_axis="model"``), a singleton ``data`` axis.

    Host-local stand-in for the multi-pod production mesh: with a forced
    device count this is what the multi-pod parity tests drive
    (``parallel.ctx.pod_count()`` picks up ``pods``).  Device count must be
    divisible by ``pods``.
    """
    n = jax.device_count()
    if pods < 1 or n % pods:
        raise ValueError(f"{n} devices not divisible into {pods} pods")
    return jax.make_mesh((pods, 1, n // pods), ("pod", "data", "model"))

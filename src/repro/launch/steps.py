"""Step builders: train / prefill / decode, shared by train.py, serve.py and
dryrun.py so the dry-run lowers EXACTLY what the launchers execute."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, input_specs
from repro.models import model as M
from repro.optim import OptConfig, apply_gradients, init_opt_state
from repro.parallel import ctx

Tree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    microbatches: int = 1):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``microbatches`` > 1 accumulates gradients with a lax.scan over batch
    slices — the compute/communication-overlap lever (XLA overlaps the DP
    reduction of microbatch i with compute of i+1).
    """

    def loss_fn(params, tokens, targets, image_embeds=None):
        return M.lm_loss(params, tokens, targets, cfg,
                         image_embeds=image_embeds)

    def train_step(params, opt_state, batch, step):
        tokens, targets = batch["tokens"], batch["targets"]
        img = batch.get("image_embeds")
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens,
                                                      targets, img)
        else:
            B = tokens.shape[0]
            mb = B // microbatches
            resh = lambda x: x.reshape(microbatches, mb, *x.shape[1:])
            mb_batch = jax.tree.map(resh, {"tokens": tokens,
                                           "targets": targets})

            def acc_fn(carry, mbk):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(
                    params, mbk["tokens"], mbk["targets"], img)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zeros), mb_batch)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state = apply_gradients(params, grads, opt_state, step,
                                            opt_cfg)
        return params, opt_state, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, batch["tokens"], cfg,
                         image_embeds=batch.get("image_embeds"))
    return prefill_step


def make_decode_step(cfg: ModelConfig, seq_shard: bool = False):
    def decode_step(params, cache, batch):
        logits, cache = M.decode_step(params, cache, batch["tokens"],
                                      batch["pos"], cfg,
                                      seq_shard=seq_shard)
        return logits, cache
    return decode_step


# --------------------------- sharding assembly ------------------------------

def resolve_tree(spec_tree: Tree):
    """Logical spec tree -> NamedSharding tree against the active mesh."""
    return ctx.map_specs(lambda s: ctx.named_sharding(tuple(s)), spec_tree)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tree:
    """Logical sharding for the input batch of this shape cell."""
    dp = ctx.axis_size("dp")
    tok = ("dp", None, None) if cfg.frontend == "audio" else ("dp", None)
    if shape.mode == "train":
        s = {"tokens": tok, "targets": tok}
    elif shape.mode == "prefill":
        s = {"tokens": tok}
    else:
        B = shape.global_batch
        btok = tok if B >= dp else ((None, None, None) if
                                    cfg.frontend == "audio" else (None, None))
        s = {"tokens": btok, "pos": (btok[0],)}
    if cfg.frontend == "vision":
        s["image_embeds"] = ("dp", None, None)
    return s


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(cfg: ModelConfig, opt_cfg: OptConfig):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params),
        opt_cfg))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, max_len))


def stacked_cache_specs(cfg: ModelConfig, batch: int) -> Tree:
    """Cache logical specs with the leading period-stack axis prepended."""
    per = M.cache_specs(cfg, batch)
    return ctx.map_specs(lambda s: (None,) + tuple(s), per)

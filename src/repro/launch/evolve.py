"""CGP approximation launcher — the paper's experiment as a CLI.

  PYTHONPATH=src python -m repro.launch.evolve --width 8 \
      --constraint "mae=0.5,er=60" --generations 2000 --seeds 3 \
      --out experiments/lib/mae05_er60.json

Multi-host / pod-sharded mode (DESIGN.md §6): launch the SAME command once
per pod with a shared --results-dir and the pod count —

  PYTHONPATH=src python -m repro.launch.evolve --width 8 \
      --constraint "mae=0.5,er=60" --seeds 30 --pods 4 \
      --results-dir /shared/sweep-shards --history summary

Each process executes its own disjoint slice of the chunk plan (pod index
auto-resolved from the mesh/process, or forced with --pod-index) and commits
its shards independently; results are bit-identical to the single-host run
and any pod can be re-launched to resume its slice.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.fitness import ConstraintSpec
from repro.core.library import save_library
from repro.core.search import SearchConfig, run_sweep_serial
from repro.core.sweep import SweepConfig, run_sweep_batched
from repro.core.evolve import EvolveConfig


def parse_constraint(s: str) -> ConstraintSpec:
    kw = {}
    for part in s.split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        k = k.strip()
        if k in ("acc0", "gauss"):
            kw[k] = v.strip().lower() in ("1", "true", "yes", "")
        else:
            kw[k] = float(v)
    return ConstraintSpec(**kw)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--kind", default="mul", choices=["mul", "add"])
    ap.add_argument("--nodes", type=int, default=400)
    ap.add_argument("--constraint", action="append", required=True,
                    help='e.g. "mae=0.5,er=60" (repeatable)')
    ap.add_argument("--generations", type=int, default=2000)
    ap.add_argument("--lam", type=int, default=8)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"],
                    help="candidate evaluation: pure-jnp or the fused "
                         "(runs x lambda) Pallas kernel (one dispatch per "
                         "generation in the batched engine; interpret on CPU)")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "genome_major", "cube_major"],
                    help="Pallas evaluation-grid order (backend=pallas, "
                         "DESIGN.md section 7): genome_major streams the "
                         "input cube per genome, cube_major reuses each "
                         "cube block across the whole population (VMEM "
                         "scratch accumulators); auto resolves the measured "
                         "tuning table.  Results are bit-identical either "
                         "way")
    ap.add_argument("--dedup", action="store_true",
                    help="phenotype-dedup evaluation cache (DESIGN.md "
                         "section 8): evaluate each unique active subgraph "
                         "once per generation and reuse cached results "
                         "across generations.  Execution-only — results are "
                         "bit-identical with or without it")
    ap.add_argument("--dedup-cache-size", type=int, default=1 << 16,
                    help="entry bound of the cross-generation phenotype LRU "
                         "(default: 65536)")
    ap.add_argument("--eval-mode", default="exhaustive",
                    choices=["exhaustive", "sampled"],
                    help="evaluation inputs (DESIGN.md section 9): "
                         "'exhaustive' scores every candidate on the full "
                         "2^(2w) cube (bit-identical to the historic "
                         "engine); 'sampled' scores them on a deterministic "
                         "--sample-size operand sample from --input-dist — "
                         "the only tractable mode past width ~10-12, with "
                         "per-metric standard errors reported")
    ap.add_argument("--sample-size", type=int, default=1 << 14,
                    help="rows per sample (eval-mode=sampled); rounded up "
                         "to a power-of-two word count x 32 lanes "
                         "(default: 16384)")
    ap.add_argument("--input-dist", default="uniform",
                    choices=["uniform", "gaussian", "empirical"],
                    help="operand distribution of the sample (DESIGN.md "
                         "section 9): uniform over [0, 2^w); gaussian "
                         "centered mid-range (sigma = 2^w/6, clipped); or "
                         "empirical — inverse-CDF draws from an activation "
                         "histogram captured off the data pipeline")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="counter-based PRNG seed of the sample stream "
                         "(deterministic + checkpoint-replayable; part of "
                         "the grid fingerprint)")
    ap.add_argument("--certify", action="store_true",
                    help="exact-verification escalation tier (DESIGN.md "
                         "section 10): after each sampled sweep chunk, the "
                         "best elites that satisfy the combined constraint "
                         "on sampled metrics are re-measured EXACTLY over "
                         "the full 2^(2w) cube (one dispatch at small "
                         "widths, a chunked bit-parallel pass at large "
                         "ones), so emitted WCE/ACC0 verdicts are "
                         "guarantees, not estimates.  No-op under "
                         "--eval-mode exhaustive (a census is already "
                         "exact)")
    ap.add_argument("--certify-budget", type=int, default=8,
                    help="base escalations per sweep chunk; the adaptive "
                         "policy ramps the cap toward exact checks as the "
                         "sweep converges (default: 8)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--export-artifacts", default=None, metavar="DIR",
                    help="after the sweep, export per-constraint elite "
                         "circuits from --results-dir as fingerprinted LUT "
                         "artifacts + registry.json (core.artifacts, "
                         "DESIGN.md section 12) into DIR — the input of "
                         "`serve --approx-lut`; equivalent to running "
                         "`python -m repro.launch.export` afterwards")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="runs per jit'd batch of the sweep engine")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="resumable sweep state; rerun with the same grid "
                         "to continue mid-grid")
    ap.add_argument("--results-dir", default=None,
                    help="stream each finished chunk to an on-disk result "
                         "shard (core.results); the shard set is resumable "
                         "and is read back with SweepResultReader")
    ap.add_argument("--history", default=None,
                    choices=["full", "summary", "none"],
                    help="per-generation history mode: 'full' keeps them in "
                         "RAM, 'summary' spills them to --results-dir only "
                         "(flat host memory), 'none' drops them "
                         "(default: full)")
    ap.add_argument("--no-history", action="store_true",
                    help="alias for --history none (kept for compatibility)")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod-shard the sweep: partition the chunk plan "
                         "over N pods and run only this process's slice "
                         "(launch once per pod with a shared --results-dir; "
                         "DESIGN.md section 6)")
    ap.add_argument("--pod-index", type=int, default=None,
                    help="which pod slice this process executes (default: "
                         "resolved from the active mesh / JAX process index)")
    ap.add_argument("--async-commit", action="store_true",
                    help="commit result shards / checkpoints on a bounded "
                         "background thread so the next chunk dispatches "
                         "while the previous one's npz write + fsync runs "
                         "(DESIGN.md section 11).  Execution-only: committed "
                         "bytes, order and crash guarantees are identical "
                         "to the synchronous path")
    ap.add_argument("--migrate-every", type=int, default=0,
                    help="island-model elite migration between pods "
                         "(DESIGN.md section 11): every N chunks of its own "
                         "slice a pod publishes its per-sigma elite genomes "
                         "to --results-dir, and later chunks fold the other "
                         "pods' published elites into their initial "
                         "population under a deterministic merge rule.  "
                         "Result-changing (joins the grid fingerprint when "
                         "on); 0 disables (default), keeping results "
                         "byte-identical to the migration-less engine")
    ap.add_argument("--migrate-timeout", type=float, default=120.0,
                    help="seconds to wait for a lagging pod's migrant file "
                         "before failing (default: 120)")
    ap.add_argument("--serial", action="store_true",
                    help="reference serial loop instead of the batched engine")
    args = ap.parse_args()
    if args.pods > 1 and not args.results_dir:
        ap.error("--pods > 1 needs a shared --results-dir (the shard set "
                 "is the only cross-pod resume state)")
    if args.serial and args.pods > 1:
        ap.error("--serial is the single-process reference loop; it cannot "
                 "pod-shard the grid (drop --serial or --pods)")
    if args.serial and args.dedup:
        ap.error("--dedup lives in the batched sweep engine; drop --serial")
    if args.serial and args.certify:
        ap.error("--certify's escalation driver lives in the batched sweep "
                 "engine; drop --serial")
    if args.serial and args.async_commit:
        ap.error("--async-commit's background committer lives in the "
                 "batched sweep engine; drop --serial")
    if args.serial and args.migrate_every:
        ap.error("--migrate-every lives in the batched sweep engine; drop "
                 "--serial")
    if args.migrate_every and not args.results_dir:
        ap.error("--migrate-every needs a --results-dir: migrant files "
                 "ride the shared results directory (DESIGN.md section 11)")
    if args.export_artifacts and not args.results_dir:
        ap.error("--export-artifacts reads the sweep back through the "
                 "results layer; it needs a --results-dir")
    if args.export_artifacts and args.serial:
        ap.error("--serial never writes result shards; drop --serial to "
                 "use --export-artifacts")
    if args.export_artifacts and args.kind != "mul":
        ap.error("--export-artifacts builds multiplier LUT artifacts; "
                 "--kind add is not exportable")

    cfg = SearchConfig(
        width=args.width, kind=args.kind, n_n=args.nodes,
        evolve=EvolveConfig(generations=args.generations, lam=args.lam,
                            backend=args.backend, layout=args.layout,
                            eval_mode=args.eval_mode,
                            sample_size=args.sample_size,
                            input_dist=args.input_dist,
                            sample_seed=args.sample_seed,
                            certify=args.certify,
                            certify_budget=args.certify_budget))
    constraints = [parse_constraint(c) for c in args.constraint]
    if args.serial:
        records = run_sweep_serial(cfg, constraints, seeds=range(args.seeds))
    else:
        mode = args.history or ("none" if args.no_history else "full")
        pod = args.pod_index
        if args.pods > 1 and pod is None:
            # resolve ONCE here so the printed label and the executed slice
            # cannot disagree
            from repro.parallel import ctx
            pod = ctx.default_pod_index(args.pods)
        sweep = SweepConfig(chunk_size=args.chunk_size,
                            checkpoint_dir=args.checkpoint_dir,
                            results_dir=args.results_dir,
                            keep_history=mode, layout=args.layout,
                            n_pods=args.pods, pod_index=pod,
                            dedup=args.dedup or None,
                            dedup_cache_size=args.dedup_cache_size,
                            async_commit=args.async_commit,
                            migrate_every=args.migrate_every,
                            migrate_timeout=args.migrate_timeout)
        result = run_sweep_batched(cfg, constraints, seeds=range(args.seeds),
                                   sweep=sweep)
        records = result.records
        tag = f"pod {pod}/{args.pods}: " if args.pods > 1 else ""
        print(f"[evolve] {tag}{result.completed}/{result.n_runs} runs "
              f"@ {result.runs_per_sec:.2f} runs/s", flush=True)
        if args.certify and result.certify_stats is not None:
            st = result.certify_stats
            print(f"[evolve] certify: {st['escalated']} escalations this "
                  f"call, {st['certified_rows']}/{result.n_runs} rows "
                  f"certified exact (budget {st['budget']}/chunk)",
                  flush=True)
        if args.migrate_every and result.migrate_stats is not None:
            # only under --migrate-every, so migration-less stdout stays
            # byte-identical to the pre-§11 CLI
            st = result.migrate_stats
            print(f"[evolve] migrate: {st['published']} epochs published, "
                  f"{st['imported']} elites imported, {st['adopted']} runs "
                  f"adopted a migrant ({st['waited_s']:.1f}s waiting on "
                  f"peers)", flush=True)
        if args.dedup and result.dedup_stats is not None:
            st = result.dedup_stats
            print(f"[evolve] dedup cache: hit rate {st['hit_rate']:.1%} "
                  f"({st['evaluated']}/{st['candidates']} candidates "
                  f"evaluated, {st['lru_hits']} LRU hits, "
                  f"{st['evictions']} evictions)", flush=True)
        if args.results_dir:
            reader = result.reader()
            print(f"[evolve] {len(reader.spans())} result shards "
                  f"({reader.completed}/{reader.n_runs} runs, history mode "
                  f"{reader.keep_history!r}) -> {args.results_dir}",
                  flush=True)
    metric_names = ("mae", "wce", "er", "mre", "avg", "acc0", "gauss")
    for r in records:
        met = {n: round(float(v), 4) for n, v in zip(metric_names, r.metrics)}
        row = {"constraint": r.constraint, "seed": r.seed,
               "power_rel": round(r.power_rel, 4),
               "feasible": r.feasible, "metrics": met}
        if args.eval_mode == "sampled":
            # per-metric standard errors (DESIGN.md §9) — the ±1 SE interval
            # downstream margin-aware thresholds consume
            row["metrics_stderr"] = {
                n: round(float(v), 6)
                for n, v in zip(metric_names, r.metrics_stderr)}
            if args.certify:
                # only under --certify, so sampled-only output stays
                # byte-identical to the pre-§10 CLI
                row["certified"] = r.certified
        print(json.dumps(row), flush=True)
    if args.out:
        save_library(records, args.out)
        print(f"[evolve] wrote {len(records)} circuits -> {args.out}")
    if args.export_artifacts:
        from repro.core.artifacts import export_elites
        registry = export_elites(args.results_dir, args.export_artifacts)
        print(f"[evolve] exported {len(registry['artifacts'])} LUT "
              f"artifact(s) -> {args.export_artifacts} "
              f"(grid {registry['grid_fingerprint'][:12]}...)", flush=True)


if __name__ == "__main__":
    main()

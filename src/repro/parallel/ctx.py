"""Parallel context: the active mesh + logical-axis resolution.

Model code never names physical mesh axes; it requests logical axes
("fsdp", "tp", "dp", "sp", "sweep") which resolve against the active mesh
set by the launcher.  With no active mesh every helper is a no-op, so the
same model code runs single-device (smoke tests) and on the production mesh
(dry-run).

The physical axes are the production mesh's (pod, data, model)
(``launch.mesh``, DESIGN.md §5).  The ``pod`` axis is deliberately
DOUBLE-MAPPED, because the two cell types use it differently:

  * LM cells fold it into data parallelism — "dp"/"fsdp"/"sp" resolve to
    ``(pod, data)`` so batch/optimizer sharding spans pods transparently;
  * CGP cells treat it as the constraint-grid partition — "sweep" resolves
    to ``(pod,)``, and the pod-sharded sweep engine (``core.sweep``,
    DESIGN.md §6) uses ``pod_count``/``default_pod_index`` below to decide
    which slice of the chunk plan this process owns.  That partition needs
    no collectives: pods only share the results manifest on disk.

"tp" (tensor parallelism) and the CGP input-cube sharding both resolve to
``model``.
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Mesh | None = None

# logical -> physical axis mapping (see module docstring for why "pod"
# appears both folded into dp/fsdp/sp and alone under "sweep")
LOGICAL = {
    "dp": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "sp": ("pod", "data"),   # sequence sharding reuses the data axis
    "tp": ("model",),
    "sweep": ("pod",),       # constraint-grid pod partition (CGP cells)
}


def set_mesh(mesh: Mesh | None) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Mesh | None:
    return _ACTIVE_MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    old = get_mesh()
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(old)


def resolve_axis(logical: str | None) -> Any:
    """Logical axis -> physical axis (subset present in the active mesh)."""
    mesh = get_mesh()
    if logical is None or mesh is None:
        return None
    phys = tuple(a for a in LOGICAL.get(logical, (logical,))
                 if a in mesh.axis_names)
    if not phys:
        return None
    return phys if len(phys) > 1 else phys[0]


def is_logical_spec(s) -> bool:
    """True for a PLAIN tuple of axis names/None (NamedTuples like SSMState
    are containers, not specs — ``type(s) is tuple`` excludes them)."""
    return (type(s) is tuple
            and all(e is None or isinstance(e, str) for e in s))


def map_specs(fn, spec_tree):
    """tree.map over a logical-spec tree (spec tuples are the leaves)."""
    import jax
    return jax.tree.map(fn, spec_tree, is_leaf=is_logical_spec)


def resolve_spec(logical_spec: tuple) -> P:
    return P(*(resolve_axis(a) for a in logical_spec))


def named_sharding(logical_spec: tuple) -> NamedSharding | None:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(logical_spec))


def shard(x: jax.Array, *logical_spec) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without)."""
    ns = named_sharding(tuple(logical_spec))
    if ns is None:
        return x
    return jax.lax.with_sharding_constraint(x, ns)


def axis_size(logical: str) -> int:
    """Total device count along a logical axis (1 with no active mesh, or
    when none of its physical axes are present)."""
    mesh = get_mesh()
    if mesh is None:
        return 1
    phys = LOGICAL.get(logical, (logical,))
    n = 1
    for a in phys:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# -- pod identity (the sweep partition, DESIGN.md §6) -----------------------

def pod_count() -> int:
    """Size of the ``pod`` axis of the active mesh (1 when no mesh is
    active or the mesh has no pod axis) — the natural ``SweepConfig.n_pods``
    for a mesh-driven launch."""
    return axis_size("sweep")


def pod_rank() -> int:
    """This process's coordinate along the active mesh's ``pod`` axis.

    Resolved from the position of the first LOCAL device in the mesh's
    device array, so on a multi-host mesh whose hosts each hold one pod
    slice it identifies the pod that this process drives.  Returns 0 when
    no mesh is active, the mesh has no ``pod`` axis, or the mesh holds no
    local device (a fully-remote mesh under single-controller dry-runs).
    Note the single-process multi-device degenerate case: a process that
    owns ALL pods reports rank 0 — pass ``SweepConfig.pod_index``
    explicitly to drive pods one by one from one process (tests do).
    """
    import numpy as np
    mesh = get_mesh()
    if mesh is None or "pod" not in mesh.axis_names:
        return 0
    pos = np.argwhere(mesh.devices == jax.local_devices()[0])
    if pos.size == 0:
        return 0
    return int(pos[0][list(mesh.axis_names).index("pod")])


def default_pod_index(n_pods: int) -> int:
    """The pod slice this process should execute, for ``SweepConfig`` users
    who leave ``pod_index=None``: the mesh pod coordinate when the active
    mesh carries a pod axis, otherwise the JAX process index
    (one-pod-per-process multi-host launches without a mesh), wrapped into
    range.  A pod axis whose size disagrees with ``n_pods`` raises — a
    silent fallback would leave some pod slices assigned to no process."""
    mesh = get_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        if mesh.shape["pod"] != n_pods:
            raise ValueError(
                f"active mesh has a {mesh.shape['pod']}-pod axis but the "
                f"sweep was configured with n_pods={n_pods}; align them or "
                f"pass pod_index explicitly")
        return pod_rank()
    return jax.process_index() % n_pods

"""Parallel context: the active mesh + logical-axis resolution.

Model code never names physical mesh axes; it requests logical axes
("fsdp", "tp", "dp", "sp") which resolve against the active mesh set by the
launcher.  With no active mesh every helper is a no-op, so the same model
code runs single-device (smoke tests) and on the production mesh (dry-run).
"""
from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Mesh | None = None

# logical -> physical axis mapping (pod axis folds into data-parallel/FSDP)
LOGICAL = {
    "dp": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "sp": ("pod", "data"),   # sequence sharding reuses the data axis
    "tp": ("model",),
}


def set_mesh(mesh: Mesh | None) -> None:
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_mesh() -> Mesh | None:
    return _ACTIVE_MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    old = get_mesh()
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(old)


def resolve_axis(logical: str | None) -> Any:
    """Logical axis -> physical axis (subset present in the active mesh)."""
    mesh = get_mesh()
    if logical is None or mesh is None:
        return None
    phys = tuple(a for a in LOGICAL.get(logical, (logical,))
                 if a in mesh.axis_names)
    if not phys:
        return None
    return phys if len(phys) > 1 else phys[0]


def is_logical_spec(s) -> bool:
    """True for a PLAIN tuple of axis names/None (NamedTuples like SSMState
    are containers, not specs — ``type(s) is tuple`` excludes them)."""
    return (type(s) is tuple
            and all(e is None or isinstance(e, str) for e in s))


def map_specs(fn, spec_tree):
    """tree.map over a logical-spec tree (spec tuples are the leaves)."""
    import jax
    return jax.tree.map(fn, spec_tree, is_leaf=is_logical_spec)


def resolve_spec(logical_spec: tuple) -> P:
    return P(*(resolve_axis(a) for a in logical_spec))


def named_sharding(logical_spec: tuple) -> NamedSharding | None:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve_spec(logical_spec))


def shard(x: jax.Array, *logical_spec) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without)."""
    ns = named_sharding(tuple(logical_spec))
    if ns is None:
        return x
    return jax.lax.with_sharding_constraint(x, ns)


def axis_size(logical: str) -> int:
    mesh = get_mesh()
    if mesh is None:
        return 1
    phys = LOGICAL.get(logical, (logical,))
    n = 1
    for a in phys:
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n

"""CGP point mutation (paper Sec. III).

Standard per-gene point mutation: every gene independently mutates with
probability ``rate`` (expected h = rate · n_genes mutated genes per
offspring).  Fan-in genes resample uniformly from the node's legal
feed-forward range, function genes from Γ, output genes from all wires — so
every offspring is legal by construction (property-tested).  The redundant
CGP encoding makes many mutations neutral, which the (1+λ) selection exploits
(offspring with *equal* fitness replace the parent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.genome import CGPSpec, Genome, max_fanin_index


def mutate(key: jax.Array, genome: Genome, spec: CGPSpec,
           rate: float = 0.05) -> Genome:
    k_sel_n, k_sel_o, k_in0, k_in1, k_fn, k_out = jax.random.split(key, 6)

    hi = jnp.asarray(max_fanin_index(spec))  # (n_n,)
    new_in0 = jax.random.randint(k_in0, (spec.n_n,), 0, hi)
    new_in1 = jax.random.randint(k_in1, (spec.n_n,), 0, hi)
    new_fn = jax.random.randint(k_fn, (spec.n_n,), 0, spec.n_funcs)
    new_nodes = jnp.stack([new_in0, new_in1, new_fn], axis=-1).astype(jnp.int32)

    mut_n = jax.random.bernoulli(k_sel_n, rate, (spec.n_n, 3))
    nodes = jnp.where(mut_n, new_nodes, genome.nodes)

    new_outs = jax.random.randint(k_out, (spec.n_o,), 0, spec.n_wires,
                                  dtype=jnp.int32)
    mut_o = jax.random.bernoulli(k_sel_o, rate, (spec.n_o,))
    outs = jnp.where(mut_o, new_outs, genome.outs)
    return Genome(nodes, outs)


def mutate_population(key: jax.Array, parent: Genome, spec: CGPSpec,
                      lam: int, rate: float = 0.05) -> Genome:
    """λ offspring of one parent (leading axis lam)."""
    keys = jax.random.split(key, lam)
    return jax.vmap(lambda k: mutate(k, parent, spec, rate))(keys)

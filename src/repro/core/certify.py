"""Exact-verification escalation tier (DESIGN.md §10).

Sampled evaluation (DESIGN.md §9) broke the 2^(2w) wall but cannot certify
WCE/ACC0/GAUSS: a sample max is only a LOWER bound on the worst case, and
the indicator metrics have no CLT interval (``metrics.metric_stderr``
reports 0 for all three) — every width ≥ 11 sweep ships *estimates* where
the paper's combined-constraint results require *guarantees*.  This module
is the second evaluation tier that fixes that, following the paper's own
follow-on work (PAPERS.md: arXiv 2003.02491 "Adaptive Verifiability-Driven
Strategy", arXiv 2205.03267 "Optimization of BDD-based Approximation Error
Metrics Calculations"): the population is screened with the cheap sampled
kernel, and only constraint-surviving elites are escalated to an EXACT
re-measurement, under an adaptive per-chunk budget (``CertifyPolicy``).

Two exact regimes, chosen STATICALLY from the cube size (``certified_metrics``):

  * **full-cube dispatch** — when the 2^(2w) cube fits one dispatch budget
    (``dispatch_rows``), the candidate is re-simulated over the exhaustive
    bit-plane cube in one jit'd pass and the materialized values are
    finalized through ``metrics.metrics_np`` — bit-identical to the
    exhaustive oracle by construction (the differential harness in
    ``tests/test_certify.py`` pins this at widths ≤ 8).
  * **chunked bit-parallel pass** — at larger widths the cube is streamed in
    ``dispatch_rows``-row slices of packed operand planes (the same
    ``(n_i, W)`` bit-plane contract the fused ``kernels/cgp_sim`` kernel
    consumes) and each slice's partials are accumulated host-side in
    int64/float64, combined per the ``MetricPartials`` shard contract
    (sum every accumulator, max ``wce_max`` — DESIGN.md §6).  MAE, WCE, ER,
    AVG, ACC0 and the Gauss histogram are integer-exact at ANY width and
    chunking; MRE is a float64 sum whose chunk-order reassociation carries
    the same documented caveat as model-axis cube shards.

The escalation driver lives in ``core.sweep.run_sweep_batched`` (gated by
``EvolveConfig.certify``); certified rows land in the results schema v3
``certified_mask`` column and ``CircuitRecord.certified``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core import simulate
from repro.core.fitness import _IS_LOWER_BOUND
from repro.core.genome import CGPSpec, Genome

#: default rows per exact dispatch: 2^20 rows keeps the live (n_wires, W)
#: simulation state of a paper-scale genome around 100 MB and amortizes the
#: per-dispatch overhead; widths ≤ 10 certify in ONE dispatch.
DISPATCH_ROWS = 1 << 20

#: metric indices a sampled estimate can NEVER certify: the sample max is a
#: lower bound (WCE) and the indicators are verdicts about the full cube
#: (ACC0, GAUSS) — exactly the positions ``metrics.metric_stderr`` zeroes.
UNCERTIFIABLE = (M.WCE, M.ACC0, M.GAUSS)


def requires_certification(thresholds) -> bool:
    """True iff the combined constraint binds a metric a sample cannot
    certify (WCE/ACC0/GAUSS — the ``metric_stderr = 0`` positions).

    This is the stderr-misuse guard of DESIGN.md §10: a sampled run whose
    constraint binds one of these metrics can satisfy it *on the sample*
    but must NOT be treated as certified-feasible without an escalation to
    the exact tier (``CircuitRecord.certified`` stays False otherwise).
    """
    t = np.asarray(thresholds)
    hard = np.zeros(M.N_METRICS, dtype=bool)
    hard[list(UNCERTIFIABLE)] = True
    # a finite threshold is a binding constraint in both encodings: upper
    # bounds are +inf when unconstrained, required booleans are -inf
    return bool((np.isfinite(t) & hard).any())


def feasible_np(metric_vec, thresholds) -> bool:
    """Host-side Eq. (9) predicate — mirrors ``fitness.feasible`` bit-for-bit
    (same lower-bound encoding for the boolean metrics)."""
    m = np.asarray(metric_vec, dtype=np.float32)
    t = np.asarray(thresholds, dtype=np.float32)
    return bool(np.where(_IS_LOWER_BOUND, m >= t, m <= t).all())


@dataclasses.dataclass(frozen=True)
class CertifyPolicy:
    """Adaptive escalation budget (arXiv 2003.02491).

    Early sweep chunks churn through candidates that later chunks supersede,
    so exact checks there are mostly wasted; as the sweep progresses the
    budget ramps toward exact verification: chunk ``i`` of ``n`` may escalate
    up to ``ceil(budget * (1 + ramp * i/(n-1)))`` elites.  ``ramp=1`` doubles
    the cap by the final chunk; ``ramp=0`` is a flat per-chunk cap.  The
    schedule is a pure function of the (deterministic, manifest-pinned)
    chunk plan, so resumed and pod-sharded sweeps budget identically.
    """
    budget: int = 8                    # base escalations per chunk
    ramp: float = 1.0                  # late-sweep budget growth factor
    dispatch_rows: int = DISPATCH_ROWS  # rows per exact dispatch chunk

    def __post_init__(self):
        if self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget}")
        if self.ramp < 0:
            raise ValueError(f"ramp must be >= 0, got {self.ramp}")
        if self.dispatch_rows < 32 or self.dispatch_rows % 32:
            raise ValueError(f"dispatch_rows must be a positive multiple of "
                             f"32, got {self.dispatch_rows}")

    def chunk_budget(self, chunk_idx: int, n_chunks: int) -> int:
        """Escalation cap of plan-chunk ``chunk_idx`` of ``n_chunks``."""
        frac = chunk_idx / max(n_chunks - 1, 1)
        return int(np.ceil(self.budget * (1.0 + self.ramp * frac)))


def select_escalations(feasible_mask, power_rel, certified_mask,
                       budget: int) -> np.ndarray:
    """Rows to escalate this chunk: sampled-feasible, not yet certified,
    best (lowest relative power — the circuits a feasible sweep would ship)
    first, capped at ``budget``.  Stable order, so the selection is a pure
    function of the chunk's measurements."""
    feas = np.asarray(feasible_mask, dtype=bool)
    done = np.asarray(certified_mask, dtype=bool)
    elig = np.flatnonzero(feas & ~done)
    order = elig[np.argsort(np.asarray(power_rel)[elig], kind="stable")]
    return order[:max(int(budget), 0)]


# --------------------------------------------------------------------------
# Exact measurement
# --------------------------------------------------------------------------

def cube_slice_planes(n_i: int, start: int, n_rows: int) -> np.ndarray:
    """(n_i, n_rows/32) int32 packed bit-planes of cube rows
    [start, start + n_rows) — ``simulate.input_planes_np`` restricted to an
    index slice (same lane packing), so the chunked exact pass feeds the
    simulator the exact contract the fused kernel consumes."""
    if n_rows % 32 or n_rows < 32:
        raise ValueError(f"n_rows must be a positive multiple of 32, "
                         f"got {n_rows}")
    xs = np.arange(start, start + n_rows, dtype=np.uint64)
    planes = []
    for i in range(n_i):
        bits = ((xs >> np.uint64(i)) & np.uint64(1)).astype(np.uint32)
        words = bits.reshape(-1, 32)
        packed = (words << np.arange(32, dtype=np.uint32)[None, :]).sum(
            axis=1, dtype=np.uint32)
        planes.append(packed)
    return np.stack(planes).astype(np.int32)  # two's complement reinterpret


@functools.partial(jax.jit, static_argnames=("spec",))
def _simulate_chunk(spec: CGPSpec, nodes: jax.Array, outs: jax.Array,
                    in_planes: jax.Array) -> jax.Array:
    """(W*32,) int32 candidate values on one packed cube slice.  One trace
    per (spec, slice shape): every chunk of a width reuses the program."""
    return simulate.simulate_values(Genome(nodes, outs), spec, in_planes)


def _golden_slice(width: int, kind: str, start: int, n_rows: int
                  ) -> np.ndarray:
    """int64 exact golden outputs on cube rows [start, start + n_rows) —
    ``golden.golden_values`` semantics without materializing the full cube."""
    xs = np.arange(start, start + n_rows, dtype=np.int64)
    a = xs & ((1 << width) - 1)
    b = xs >> width
    if kind == "mul":
        return a * b
    if kind == "add":
        return a + b
    raise ValueError(kind)


def certified_metrics(nodes, outs, spec: CGPSpec, kind: str, width: int,
                      gauss_sigma: float, dispatch_rows: int = DISPATCH_ROWS,
                      n_gauss_side: int = 4,
                      gauss_slack: float = 1.0) -> np.ndarray:
    """EXACT (N_METRICS,) float32 metric vector over the full 2^(2w) cube.

    Full-cube dispatch when the cube fits ``dispatch_rows`` (finalized via
    ``metrics.metrics_np`` on the materialized values — bit-identical to the
    exhaustive oracle); otherwise the chunked bit-parallel pass (module
    docstring).  ``gauss_sigma``/``n_gauss_side``/``gauss_slack`` must match
    the screening tier's so the certified verdict answers the same
    constraint.
    """
    n = 1 << spec.n_i
    nodes_j = jnp.asarray(nodes)
    outs_j = jnp.asarray(outs)
    if n <= dispatch_rows:
        in_planes = simulate.input_planes(spec.n_i)
        # sub-word cubes are tiled to 32 lanes; the first n values are the
        # true cube, so slicing keeps the oracle comparison exact
        cvals = np.asarray(
            _simulate_chunk(spec, nodes_j, outs_j, in_planes))[:n]
        gvals = _golden_slice(width, kind, 0, n)
        return M.metrics_np(gvals, cvals, spec.n_o, gauss_sigma,
                            n_gauss_side, gauss_slack)

    # chunked pass: host-side int64/float64 partials, combined per the
    # MetricPartials contract (psum everything, pmax wce_max)
    chunk = 1 << (int(dispatch_rows).bit_length() - 1)  # pow2 divides pow2 n
    chunk = max(32, min(chunk, n))
    edges = M.gauss_bin_edges(gauss_sigma, n_gauss_side)
    abs_sum = sgn_sum = err_count = acc0_bad = 0
    wce = 0
    rel_sum = 0.0
    hist = np.zeros(len(edges) + 1, dtype=np.int64)
    for start in range(0, n, chunk):
        planes = jnp.asarray(cube_slice_planes(spec.n_i, start, chunk))
        cvals = np.asarray(
            _simulate_chunk(spec, nodes_j, outs_j, planes)).astype(np.int64)
        g = _golden_slice(width, kind, start, chunk)
        diff = g - cvals
        ad = np.abs(diff)
        abs_sum += int(ad.sum())
        wce = max(wce, int(ad.max()))
        err_count += int((diff != 0).sum())
        rel_sum += float((ad / np.maximum(g, 1)).sum())
        sgn_sum += int(diff.sum())
        acc0_bad += int(((g == 0) & (cvals != 0)).sum())
        idx = np.searchsorted(edges, diff.astype(np.float64), side="right")
        hist += np.bincount(idx[diff != 0], minlength=len(edges) + 1)

    out_range = float(1 << spec.n_o)
    mass = M.gauss_bin_mass(gauss_sigma, n_gauss_side)
    gauss_ok = float(np.all(hist <= mass * n * gauss_slack))
    return np.array([
        100.0 * (abs_sum / n) / out_range,
        100.0 * wce / out_range,
        100.0 * (err_count / n),
        100.0 * (rel_sum / n),
        100.0 * abs(sgn_sum / n) / out_range,
        float(acc0_bad == 0),
        gauss_ok,
    ], dtype=np.float32)

"""Combined-constraint fitness — paper Eq. (8) extended to Eq. (9).

    f(C) = cost(C)   if  ∧_i error_i(G, C) ≤ T_i
           ∞         otherwise

Thresholds are a dense (N_METRICS,) float32 vector aligned with
``metrics.METRIC_NAMES``; unconstrained entries are +inf.  The boolean metrics
(ACC0, GAUSS) are encoded as *required levels*: threshold 1.0 means "must
hold" (metric value must be ≥ 1), -inf means unconstrained — this keeps the
whole predicate a single vectorized comparison, which matters because the pod
axis shards over *threshold configurations* (DESIGN.md §2, the paper's
27k-run sweep).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class ConstraintSpec:
    """Human-friendly constraint configuration (thresholds in paper units).

    mae/wce/avg are relative-% of the output range; er/mre are %;
    acc0/gauss are "must hold" booleans; gauss_sigma parameterizes Gauss_σ.
    """
    mae: float = INF
    wce: float = INF
    er: float = INF
    mre: float = INF
    avg: float = INF
    acc0: bool = False
    gauss: bool = False
    gauss_sigma: float = 256.0

    def thresholds(self) -> np.ndarray:
        t = np.full((M.N_METRICS,), INF, dtype=np.float32)
        t[M.MAE], t[M.WCE], t[M.ER] = self.mae, self.wce, self.er
        t[M.MRE], t[M.AVG] = self.mre, self.avg
        # boolean metrics: feasible iff value >= required level
        t[M.ACC0] = 1.0 if self.acc0 else -INF
        t[M.GAUSS] = 1.0 if self.gauss else -INF
        return t

    def describe(self) -> str:
        parts = []
        for name, v in (("mae", self.mae), ("wce", self.wce), ("er", self.er),
                        ("mre", self.mre), ("avg", self.avg)):
            if np.isfinite(v):
                parts.append(f"{name}<={v:g}%")
        if self.acc0:
            parts.append("acc0")
        if self.gauss:
            parts.append(f"gauss(sigma={self.gauss_sigma:g})")
        return "+".join(parts) if parts else "unconstrained"


# boolean metrics are lower-bounded, magnitude metrics upper-bounded
_IS_LOWER_BOUND = np.zeros((M.N_METRICS,), dtype=bool)
_IS_LOWER_BOUND[M.ACC0] = True
_IS_LOWER_BOUND[M.GAUSS] = True


def feasible(metric_vec: jax.Array, thresholds: jax.Array) -> jax.Array:
    """Eq. (9) predicate: ∧_i error_i ≤ T_i (≥ for required booleans)."""
    lb = jnp.asarray(_IS_LOWER_BOUND)
    ok = jnp.where(lb, metric_vec >= thresholds, metric_vec <= thresholds)
    return jnp.all(ok)


def fitness(cost: jax.Array, metric_vec: jax.Array,
            thresholds: jax.Array) -> jax.Array:
    """Eq. (8)/(9): cost if all constraints hold else +inf."""
    return jnp.where(feasible(metric_vec, thresholds), cost, jnp.inf)

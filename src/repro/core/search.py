"""High-level search API: one call = one paper-style approximation run.

``run_search`` is the programmatic entry point used by the examples and the
benchmark harness; ``run_sweep`` executes a grid of constraint configurations
(the paper's experimental methodology, Sec. IV) and returns all evolved
circuits with their final measurements, ready for Pareto analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import golden as G
from repro.core import metrics as M
from repro.core import simulate
from repro.core.evolve import EvolveConfig, EvolveResult, evolve
from repro.core.fitness import ConstraintSpec
from repro.core.genome import CGPSpec, Genome
from repro.core.power import circuit_cost_from_probs


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    width: int = 8               # operand bit width (paper: 8x8 multiplier)
    kind: str = "mul"            # "mul" | "add"
    n_n: int = 400               # CGP nodes (paper: 400)
    evolve: EvolveConfig = EvolveConfig()


@dataclasses.dataclass
class CircuitRecord:
    """One evolved circuit with its full characterization."""
    genome_nodes: np.ndarray
    genome_outs: np.ndarray
    metrics: np.ndarray          # (N_METRICS,) final metric vector
    power_rel: float             # power(C)/power(G)
    constraint: str              # human-readable constraint description
    seed: int
    feasible: bool
    error_mean: float = 0.0      # signed error mean (Fig. 13 analyses)
    error_std: float = 0.0
    # (N_METRICS,) standard errors of the final metrics (DESIGN.md §9):
    # all-zero under eval_mode="exhaustive" (a census has no sampling
    # error), CLT estimates from the sample second moments when sampled.
    metrics_stderr: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(M.N_METRICS, np.float32))
    # True when ``metrics`` is EXACT over the full input cube (DESIGN.md
    # §10): always under eval_mode="exhaustive" (the census is its own
    # certificate); for sampled runs only after the exact-verification
    # escalation tier (``core.certify``) re-measured this circuit — sampled
    # WCE/ACC0/GAUSS values are otherwise only lower bounds / on-sample
    # verdicts and cannot certify a hard constraint.
    certified: bool = False


def problem_arrays(cfg: SearchConfig):
    """(golden genome, spec, in_planes, golden values, golden power).

    ``cfg.evolve.eval_mode`` picks the evaluation-input pair (DESIGN.md §9):
    the exhaustive 2^(2w) cube (historic default, bit-identical arrays), or
    a deterministic ``core.sampling`` operand sample packed into the same
    bit-plane/golden-value contract — the ONE branch point of the sampled
    mode; every consumer downstream is mode-agnostic.  The golden power
    normalizer is measured on the same inputs as the candidates (under
    sampling it becomes a sample estimate of the activity model, consistent
    across the numerator and denominator of ``power_rel``).
    """
    build = G.array_multiplier if cfg.kind == "mul" else G.ripple_carry_adder
    gold, spec = build(cfg.width, n_n=cfg.n_n)
    ecfg = cfg.evolve
    if ecfg.eval_mode == "sampled":
        from repro.core import sampling
        planes_np, gvals_np = sampling.sample_problem(
            cfg.width, cfg.kind, ecfg.sample_size, ecfg.input_dist,
            ecfg.sample_seed)
        in_planes = jnp.asarray(planes_np)
        gvals = jnp.asarray(gvals_np)
    else:
        in_planes = simulate.input_planes(spec.n_i)
        gvals = jnp.asarray(G.golden_values(cfg.width, cfg.kind))
    wires = simulate.simulate_planes(gold, spec, in_planes)
    probs = simulate.signal_probabilities(wires[spec.n_i:])
    gpower = circuit_cost_from_probs(gold, spec, probs).power
    return gold, spec, in_planes, gvals, gpower


def run_search(cfg: SearchConfig, constraint: ConstraintSpec,
               seed: int = 0) -> tuple[CircuitRecord, EvolveResult]:
    """One (1+λ) run under one combined constraint (paper Eq. 8/9)."""
    gold, spec, in_planes, gvals, gpower = problem_arrays(cfg)
    # NOTE: cfg.evolve.seed is deliberately NOT replaced — the PRNG key below
    # carries the seed, and EvolveConfig is a jit static arg, so baking the
    # seed in would re-trace `evolve` for every run of a sweep.
    ecfg = dataclasses.replace(cfg.evolve,
                               gauss_sigma=constraint.gauss_sigma)
    thr = jnp.asarray(constraint.thresholds())
    res = evolve(spec, ecfg, gold, thr, in_planes, gvals, gpower,
                 jax.random.PRNGKey(seed))
    rec = characterize(res.parent, spec, cfg, constraint, seed,
                       in_planes, gvals, gpower)
    return rec, res


def characterize(genome: Genome, spec: CGPSpec, cfg: SearchConfig,
                 constraint: ConstraintSpec, seed: int,
                 in_planes, gvals, gpower) -> CircuitRecord:
    """Full final measurement of an evolved circuit."""
    wires = simulate.simulate_planes(genome, spec, in_planes)
    cvals = simulate.unpack_values(wires[genome.outs])
    partials = M.error_partials(gvals, cvals, constraint.gauss_sigma,
                                n_bits=spec.n_o)
    met = M.finalize_metrics(partials, spec.n_o, constraint.gauss_sigma)
    if cfg.evolve.eval_mode == "sampled":
        stderr = np.asarray(M.metric_stderr(partials, spec.n_o))
    else:  # census: zero sampling error by construction
        stderr = np.zeros(M.N_METRICS, np.float32)
    probs = simulate.signal_probabilities(wires[spec.n_i:])
    cost = circuit_cost_from_probs(genome, spec, probs)
    emean, estd = M.error_moments(gvals, cvals)
    from repro.core.fitness import feasible as feas_fn
    feas = feas_fn(met, jnp.asarray(constraint.thresholds()))
    return CircuitRecord(
        genome_nodes=np.asarray(genome.nodes),
        genome_outs=np.asarray(genome.outs),
        metrics=np.asarray(met),
        power_rel=float(cost.power / gpower),
        constraint=constraint.describe(),
        seed=seed,
        feasible=bool(feas),
        error_mean=float(emean),
        error_std=float(estd),
        metrics_stderr=stderr,
        # serial path has no escalation driver: exact iff exhaustive
        certified=cfg.evolve.eval_mode != "sampled",
    )


def run_sweep(cfg: SearchConfig, constraints: Sequence[ConstraintSpec],
              seeds: Sequence[int] = (0,), *,
              sweep=None) -> list[CircuitRecord]:
    """Grid of constraint configs × seeds (paper Sec. IV methodology).

    Executed by the batched engine (``core.sweep``): the whole grid runs as
    vmapped chunks of one jit'd program instead of a serial Python loop.
    With ``cfg.evolve.backend="pallas"`` each chunk generation evaluates its
    whole (chunk × λ) population in ONE fused kernel dispatch (the genome
    axis on the Pallas grid); results stay bit-identical to the serial loop.

    Args:
      cfg: the problem (operand ``width``, ``kind``, CGP geometry, evolve
        budget).  ``cfg.evolve.seed`` is ignored — each run's PRNG stream is
        ``PRNGKey(seed)``, so a run's result depends only on its own
        ``(constraint, seed)`` pair, never on the rest of the grid (grids
        sharing a config row share its result bit-for-bit).
      constraints: grid rows, outer loop of the run order.
      seeds: inner loop of the run order.
      sweep: ``sweep.SweepConfig`` execution knobs — chunking, checkpoint
        resume, ``keep_history`` mode and the streaming ``results_dir``
        spill (``core.results``).  Default: ``keep_history="none"``, no
        spill (per-generation histories are unreachable through this
        records-only API; set a ``results_dir`` and read them back through
        ``results.SweepResultReader``, or call ``run_sweep_batched``).

    Returns:
      ``CircuitRecord`` list in grid order (constraints outer, seeds inner),
      one per completed run — identical to ``run_sweep_serial``.
    """
    from repro.core.sweep import SweepConfig, run_sweep_batched
    sweep = sweep or SweepConfig(keep_history="none")
    return run_sweep_batched(cfg, constraints, seeds, sweep).records


def run_sweep_serial(cfg: SearchConfig, constraints: Sequence[ConstraintSpec],
                     seeds: Sequence[int] = (0,)) -> list[CircuitRecord]:
    """Reference serial loop (one ``evolve`` dispatch per run).

    Kept as the equivalence oracle for the batched engine (tests) and the
    baseline of the ``sweep`` microbenchmark.
    """
    records = []
    for con in constraints:
        for seed in seeds:
            rec, _ = run_search(cfg, con, seed)
            records.append(rec)
    return records

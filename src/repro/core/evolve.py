"""(1+λ) error-oriented CGP evolution — paper Sec. III-B / IV.

Single-island semantics (paper-faithful):
  parent ← golden circuit
  repeat: λ offspring by point mutation; evaluate Eq.(8)/(9) fitness
          (power if all error constraints hold else ∞); offspring with
          fitness ≤ parent replaces it (neutral drift enabled).

Distributed semantics (DESIGN.md §2 — the TPU-cluster formulation):
  mesh axes  pod  × data × model
             │       │       └─ input-space sharding: each shard simulates a
             │       │          2^n_i/axis slice of the cube; metric partials
             │       │          and signal-prob popcounts combine with psum.
             │       └─ islands: independent (1+λ) runs; every
             │          ``migrate_every`` generations the globally best parent
             │          is broadcast and replaces strictly-worse parents.
             └─ constraint-configuration sweep: every pod slice evolves under
                its own threshold vector (the paper's 27k-run experiment grid).

Everything is jit-compiled; the generation loop is a ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core import simulate
from repro.core.fitness import fitness as fitness_fn
from repro.core.genome import CGPSpec, Genome
from repro.core.mutate import mutate_population
from repro.core.power import CircuitCost, circuit_cost_from_probs
# Imported at module scope rather than inside the (jit-traced) eval path:
# every backend path shares one ops module and its process-wide
# interpret-mode pin (see ops.default_interpret).
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class EvolveConfig:
    generations: int = 2000
    lam: int = 4                 # λ offspring per generation
    # per-gene mutation probability.  0.004 ≈ 5 mutated genes for the paper's
    # 400-node genome — measured 10-20% better power at equal budget than the
    # 5%-of-genes setting, which cannot descend from the exact seed under
    # tight constraints (EXPERIMENTS.md §Perf hillclimb C4).
    mutation_rate: float = 0.004
    migrate_every: int = 64      # island migration period (distributed mode)
    gauss_sigma: float = 256.0
    seed: int = 0
    backend: str = "jnp"         # "jnp" | "pallas" candidate evaluation
    # Pallas evaluation-grid order (DESIGN.md §7): "genome_major",
    # "cube_major", or "auto" (tuning-table resolution via kernels.tune).
    # Pure execution knob — results are bit-identical across layouts, so it
    # is deliberately NOT part of the sweep grid fingerprint (checkpoints /
    # result shards resume across layout changes).  Ignored by backend="jnp".
    layout: str = "auto"
    # Phenotype-dedup evaluation cache (DESIGN.md §8): the batched sweep
    # engine canonicalizes+hashes each offspring's active subgraph, skips
    # the kernel for phenotypes already seen (within the generation or in
    # the cross-generation LRU) and scatters the cached result back.  Like
    # ``layout`` this is a pure execution knob — results are bit-identical
    # with the cache on or off, so it is NOT part of the grid fingerprint
    # and checkpoints/shards resume across the setting.  Honored by
    # ``core.sweep.run_sweep_batched`` (the serial ``evolve`` path and
    # model-axis-sharded dispatches ignore it).
    dedup: bool = False
    # Evaluation-input mode (DESIGN.md §9).  "exhaustive" scores candidates
    # on the full 2^(2w) input cube (the historic default — bit-identical to
    # the pre-§9 engine); "sampled" scores them on a deterministic
    # ``sample_size``-row operand sample drawn from ``input_dist`` with the
    # counter-based stream seeded by ``sample_seed`` (``core.sampling``).
    # UNLIKE layout/dedup this is result-changing: it IS part of the sweep
    # grid fingerprint and of the dedup cache scope (via the sample-stream
    # fingerprint).  The evolve/sweep engine itself only consumes whatever
    # (in_planes, golden_vals) it is handed — the mode picks which pair
    # ``search.problem_arrays`` builds, so sample shards reuse the cube-shard
    # psum/pmax contract unchanged.
    eval_mode: str = "exhaustive"    # "exhaustive" | "sampled"
    sample_size: int = 1 << 14       # rows (rounded up to pow2 words * 32)
    input_dist: str = "uniform"      # "uniform" | "gaussian" | "empirical"
    sample_seed: int = 0             # sample-stream seed (not the CGP seed)
    # Exact-verification escalation tier (DESIGN.md §10, ``core.certify``):
    # after each sampled sweep chunk is characterized, elites that satisfy
    # the combined constraint ON THE SAMPLE are re-measured EXACTLY over the
    # full 2^(2w) cube (full-cube dispatch or chunked bit-parallel pass),
    # capped per chunk by the adaptive ``CertifyPolicy`` built from
    # ``certify_budget``.  Result-changing for sampled grids (escalated
    # rows' shard metrics become exact), so like ``eval_mode`` it joins the
    # grid fingerprint — but ONLY when on, keeping pre-§10 sampled and all
    # exhaustive fingerprints byte-identical.  No-op under exhaustive
    # evaluation (the census is its own certificate) and on the serial
    # ``evolve`` path.
    certify: bool = False
    certify_budget: int = 8          # base escalations per sweep chunk

    def __post_init__(self):
        if self.eval_mode not in ("exhaustive", "sampled"):
            raise ValueError(f"eval_mode must be 'exhaustive' or 'sampled', "
                             f"got {self.eval_mode!r}")
        from repro.core.sampling import INPUT_DISTS
        if self.input_dist not in INPUT_DISTS:
            raise ValueError(f"input_dist must be one of {INPUT_DISTS}, "
                             f"got {self.input_dist!r}")
        if self.sample_size < 1:
            raise ValueError(
                f"sample_size must be >= 1, got {self.sample_size}")
        if self.certify_budget < 1:
            raise ValueError(
                f"certify_budget must be >= 1, got {self.certify_budget}")


class EvalResult(NamedTuple):
    metric_vec: jax.Array   # (N_METRICS,)
    cost: CircuitCost


class EvolveState(NamedTuple):
    parent: Genome
    parent_fit: jax.Array
    parent_metrics: jax.Array
    parent_power: jax.Array
    best: Genome            # best-ever feasible candidate
    best_fit: jax.Array
    key: jax.Array


class EvolveResult(NamedTuple):
    parent: Genome
    best: Genome
    best_fit: jax.Array
    # per-generation history of the parent: power_rel, metric vec, feasible
    hist_power_rel: jax.Array   # (gens,)
    hist_metrics: jax.Array     # (gens, N_METRICS)
    hist_fit: jax.Array         # (gens,)


# --------------------------------------------------------------------------
# Candidate evaluation
# --------------------------------------------------------------------------

def _eval_jnp(genome: Genome, spec: CGPSpec, in_planes: jax.Array,
              golden_vals: jax.Array, gauss_sigma: float,
              axis_name: str | None) -> EvalResult:
    """Pure-jnp evaluation over (a slice of) the input cube."""
    wires = simulate.simulate_planes(genome, spec, in_planes)
    cand_vals = simulate.unpack_values(wires[genome.outs])
    partials = M.error_partials(golden_vals, cand_vals, gauss_sigma,
                                n_bits=spec.n_o)
    pop = jax.lax.population_count(
        wires[spec.n_i:].view(jnp.uint32)).astype(jnp.float32).sum(axis=-1)
    if axis_name is not None:
        partials = M.combine_partials(partials, axis_name)
        pop = jax.lax.psum(pop, axis_name)
    n_total = partials.count.astype(jnp.float32)
    probs = pop / n_total
    metric_vec = M.finalize_metrics(partials, spec.n_o, gauss_sigma)
    cost = circuit_cost_from_probs(genome, spec, probs, with_delay=False)
    return EvalResult(metric_vec, cost)


def _eval_pallas(genome: Genome, spec: CGPSpec, in_planes: jax.Array,
                 golden_vals: jax.Array, gauss_sigma: float,
                 axis_name: str | None) -> EvalResult:
    """Fused Pallas sim+metrics kernel path (interpret=True on CPU)."""
    partials, pop = kops.cgp_eval(genome, spec, in_planes, golden_vals,
                                  gauss_sigma)
    if axis_name is not None:
        partials = M.combine_partials(partials, axis_name)
        pop = jax.lax.psum(pop, axis_name)
    n_total = partials.count.astype(jnp.float32)
    probs = pop / n_total
    metric_vec = M.finalize_metrics(partials, spec.n_o, gauss_sigma)
    cost = circuit_cost_from_probs(genome, spec, probs, with_delay=False)
    return EvalResult(metric_vec, cost)


def get_eval_fn(backend: str) -> Callable[..., EvalResult]:
    return {"jnp": _eval_jnp, "pallas": _eval_pallas}[backend]


def _eval_pop_jnp(genomes: Genome, spec: CGPSpec, in_planes: jax.Array,
                  golden_vals: jax.Array, gauss_sigma: float,
                  axis_name: str | None, layout: str = "auto") -> EvalResult:
    """Population (leading-R) evaluation: vmap of the per-genome jnp path.
    ``layout`` is a Pallas-grid knob and is ignored here."""
    return jax.vmap(lambda g: _eval_jnp(g, spec, in_planes, golden_vals,
                                        gauss_sigma, axis_name))(genomes)


def _eval_pop_pallas(genomes: Genome, spec: CGPSpec, in_planes: jax.Array,
                     golden_vals: jax.Array, gauss_sigma: float,
                     axis_name: str | None, layout: str = "auto"
                     ) -> EvalResult:
    """Population evaluation as ONE fused kernel dispatch.

    The stacked genome axis lands on the Pallas grid instead of a vmap
    batching dimension (``kernels.ops.cgp_eval_batched``); ``layout`` picks
    the grid order — genome-major or the transposed cube-major grid
    (DESIGN.md §7), bit-identical results either way.  Input-space sharding
    (``axis_name``) stays fused: each shard dispatches the same grid on its
    cube slice and the per-genome accumulators psum/pmax across the axis
    inside the kernel wrapper (the cube-shard variant, DESIGN.md §6) — the
    partials and popcounts coming back are already cube-global.
    """
    partials, pops = kops.cgp_eval_batched(genomes, spec, in_planes,
                                           golden_vals, gauss_sigma,
                                           axis_name=axis_name,
                                           layout=layout)
    n_total = partials.count.astype(jnp.float32)            # (R,)
    probs = pops / n_total[:, None]
    metric_vec = jax.vmap(
        lambda p: M.finalize_metrics(p, spec.n_o, gauss_sigma))(partials)
    cost = jax.vmap(lambda g, pr: circuit_cost_from_probs(
        g, spec, pr, with_delay=False))(genomes, probs)
    return EvalResult(metric_vec, cost)


def get_population_eval(backend: str) -> Callable[..., EvalResult]:
    """Evaluation of (R,)-stacked genomes -> EvalResult with leading R."""
    return {"jnp": _eval_pop_jnp, "pallas": _eval_pop_pallas}[backend]


# --------------------------------------------------------------------------
# Generation step / scan loop
# --------------------------------------------------------------------------

def _select(state: EvolveState, offspring: Genome, fits: jax.Array,
            mets: jax.Array, powers: jax.Array) -> EvolveState:
    i = jnp.argmin(fits)
    off_best = jax.tree.map(lambda x: x[i], offspring)
    take = fits[i] <= state.parent_fit  # '≤' enables neutral drift
    pick = lambda a, b: jax.tree.map(
        lambda x, y: jnp.where(take, x, y), a, b)
    parent = pick(off_best, state.parent)
    parent_fit = jnp.where(take, fits[i], state.parent_fit)
    parent_metrics = jnp.where(take, mets[i], state.parent_metrics)
    parent_power = jnp.where(take, powers[i], state.parent_power)
    improves = fits[i] < state.best_fit
    best = jax.tree.map(lambda x, y: jnp.where(improves, x, y),
                        off_best, state.best)
    best_fit = jnp.minimum(fits[i], state.best_fit)
    return EvolveState(parent, parent_fit, parent_metrics, parent_power,
                       best, best_fit, state.key)


def make_generation_step(spec: CGPSpec, cfg: EvolveConfig,
                         axis_name: str | None = None,
                         island_axis: str | None = None):
    """Build the jit-able one-generation function.

    Returns step(state, thresholds, in_planes, golden_vals, gen_idx) -> state.
    """
    eval_pop = get_population_eval(cfg.backend)

    def step(state: EvolveState, thresholds, in_planes, golden_vals, gen_idx):
        key, k_mut = jax.random.split(state.key)
        offspring = mutate_population(k_mut, state.parent, spec, cfg.lam,
                                      cfg.mutation_rate)
        res = eval_pop(offspring, spec, in_planes, golden_vals,
                       cfg.gauss_sigma, axis_name, cfg.layout)
        fits = jax.vmap(fitness_fn)(res.cost.power,
                                    res.metric_vec,
                                    jnp.broadcast_to(thresholds,
                                                     (cfg.lam,) + thresholds.shape))
        state = _select(state._replace(key=key), offspring, fits,
                        res.metric_vec, res.cost.power)

        if island_axis is not None:
            state = jax.lax.cond(
                (gen_idx + 1) % cfg.migrate_every == 0,
                lambda s: _migrate(s, island_axis),
                lambda s: s, state)
        return state

    return step


def _migrate(state: EvolveState, axis: str) -> EvolveState:
    """Broadcast the globally best parent to strictly-worse islands."""
    all_fit = jax.lax.all_gather(state.parent_fit, axis)       # (n_isl,)
    all_parent = jax.lax.all_gather(state.parent, axis)        # stacked tree
    j = jnp.argmin(all_fit)
    g_best_fit = all_fit[j]
    g_best = jax.tree.map(lambda x: x[j], all_parent)
    worse = state.parent_fit > g_best_fit
    parent = jax.tree.map(lambda a, b: jnp.where(worse, a, b),
                          g_best, state.parent)
    parent_fit = jnp.where(worse, g_best_fit, state.parent_fit)
    return state._replace(parent=parent, parent_fit=parent_fit)


def init_state(spec: CGPSpec, cfg: EvolveConfig, golden: Genome,
               thresholds: jax.Array, in_planes: jax.Array,
               golden_vals: jax.Array, key: jax.Array,
               axis_name: str | None = None) -> EvolveState:
    eval_fn = get_eval_fn(cfg.backend)
    res = eval_fn(golden, spec, in_planes, golden_vals, cfg.gauss_sigma,
                  axis_name)
    fit = fitness_fn(res.cost.power, res.metric_vec, thresholds)
    return EvolveState(golden, fit, res.metric_vec, res.cost.power,
                       golden, fit, key)


def make_batched_generation_step(spec: CGPSpec, cfg: EvolveConfig,
                                 axis_name: str | None = None):
    """Run-batched one-generation function for the batched sweep engine.

    ``state`` leaves and ``thr_mat`` carry a leading run axis C.  Mutation
    and selection are vmapped per run (preserving each run's PRNG stream
    exactly as the serial path draws it), but the (C × λ) offspring
    population is FLATTENED and evaluated in one shot — for
    ``backend="pallas"`` that is a single fused kernel dispatch with
    R = C·λ genomes on the grid, instead of a vmap-of-vmap-of-pallas_call.
    Same positional signature as ``make_generation_step``'s result, so it
    drops into ``scan_generations`` directly.

    ``axis_name`` enables input-space sharding of the fused dispatch
    (DESIGN.md §6): ``in_planes``/``golden_vals`` are this shard's cube
    slice and the evaluation partials combine across the axis, so the whole
    (C × λ) population still evaluates as one (sharded) dispatch per
    generation.  Mutation/selection run on per-run state that shard_map
    replicates, so the step must execute under a context binding the axis.
    """
    eval_pop = get_population_eval(cfg.backend)

    def step(state: EvolveState, thr_mat, in_planes, golden_vals, gen_idx):
        C = thr_mat.shape[0]
        keys = jax.vmap(jax.random.split)(state.key)        # (C, 2, 2)
        key, k_mut = keys[:, 0], keys[:, 1]
        offspring = jax.vmap(
            lambda k, p: mutate_population(k, p, spec, cfg.lam,
                                           cfg.mutation_rate))(k_mut,
                                                               state.parent)
        flat = jax.tree.map(
            lambda x: x.reshape((C * cfg.lam,) + x.shape[2:]), offspring)
        res = eval_pop(flat, spec, in_planes, golden_vals, cfg.gauss_sigma,
                       axis_name, cfg.layout)
        res = jax.tree.map(
            lambda x: x.reshape((C, cfg.lam) + x.shape[1:]), res)
        fits = jax.vmap(lambda p, m, t: jax.vmap(fitness_fn)(
            p, m, jnp.broadcast_to(t, (cfg.lam,) + t.shape)))(
                res.cost.power, res.metric_vec, thr_mat)
        return jax.vmap(_select)(state._replace(key=key), offspring, fits,
                                 res.metric_vec, res.cost.power)

    return step


def init_state_batched(spec: CGPSpec, cfg: EvolveConfig, golden: Genome,
                       thr_mat: jax.Array, in_planes: jax.Array,
                       golden_vals: jax.Array, keys: jax.Array,
                       axis_name: str | None = None) -> EvolveState:
    """Per-run init for the batched sweep: the golden parent is evaluated
    ONCE (it is identical for every run) and broadcast over the run axis;
    only fitness differs per run (per-run thresholds).  ``axis_name`` shards
    the golden evaluation over the cube like the generation step's."""
    eval_fn = get_eval_fn(cfg.backend)
    res = eval_fn(golden, spec, in_planes, golden_vals, cfg.gauss_sigma,
                  axis_name)
    C = thr_mat.shape[0]
    fit = jax.vmap(
        lambda t: fitness_fn(res.cost.power, res.metric_vec, t))(thr_mat)
    rep = lambda x: jnp.broadcast_to(x, (C,) + x.shape)
    parent = jax.tree.map(rep, golden)
    return EvolveState(parent, fit, rep(res.metric_vec), rep(res.cost.power),
                       parent, fit, keys)


# --------------------------------------------------------------------------
# Dedup-path jit segments (DESIGN.md §8)
# --------------------------------------------------------------------------
#
# The phenotype-dedup sweep path (``core.sweep``) cannot run the generation
# loop as one ``lax.scan``: the dedup decision (which offspring share an
# active subgraph, which phenotypes are already cached) is host-side Python
# between kernel dispatches.  The loop is therefore split into three jit'd
# segments per generation — mutate, evaluate-uniques, select — that together
# perform EXACTLY the computation of ``make_batched_generation_step``'s one
# fused step (same PRNG splits, same op order), so results stay bit-identical
# to the scanned path with the cache on or off.

@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def mutate_segment(spec: CGPSpec, cfg: EvolveConfig, state: EvolveState
                   ) -> tuple[jax.Array, Genome]:
    """Per-run PRNG split + λ offspring; the batched step's first half.

    Returns (next keys (C, 2), offspring with leading (C, λ)).
    """
    keys = jax.vmap(jax.random.split)(state.key)        # (C, 2, 2)
    offspring = jax.vmap(
        lambda k, p: mutate_population(k, p, spec, cfg.lam,
                                       cfg.mutation_rate))(keys[:, 1],
                                                           state.parent)
    return keys[:, 0], offspring


@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def eval_segment(spec: CGPSpec, cfg: EvolveConfig, nodes: jax.Array,
                 outs: jax.Array, in_planes: jax.Array,
                 golden_vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Population evaluation of a (U,)-stacked unique-phenotype batch.

    Returns the phenotype-invariant projection the dedup cache stores:
    (metric_vec (U, N_METRICS), power (U,)).  Traced once per padded batch
    size U (the dedup driver pads to power-of-two buckets to bound
    retraces).
    """
    res = get_population_eval(cfg.backend)(
        Genome(nodes, outs), spec, in_planes, golden_vals, cfg.gauss_sigma,
        None, cfg.layout)
    return res.metric_vec, res.cost.power


@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def select_segment(spec: CGPSpec, cfg: EvolveConfig, state: EvolveState,
                   key: jax.Array, offspring: Genome, metric_vec: jax.Array,
                   power: jax.Array, thr_mat: jax.Array,
                   golden_power: jax.Array):
    """Fitness + (1+λ) selection; the batched step's second half.

    ``metric_vec``/``power`` carry leading (C, λ) — gathered from the dedup
    cache.  Emits the same per-generation history row ``scan_generations``
    traces, so the host loop can assemble bit-identical histories.
    """
    fits = jax.vmap(lambda p, m, t: jax.vmap(fitness_fn)(
        p, m, jnp.broadcast_to(t, (cfg.lam,) + t.shape)))(
            power, metric_vec, thr_mat)
    state = jax.vmap(_select)(state._replace(key=key), offspring, fits,
                              metric_vec, power)
    out = (state.parent_power / golden_power, state.parent_metrics,
           state.parent_fit)
    return state, out


def scan_generations(step, state0: EvolveState, thresholds: jax.Array,
                     in_planes: jax.Array, golden_vals: jax.Array,
                     golden_power: jax.Array, generations: int):
    """Scan ``step`` over ``generations``, tracing the parent history.

    Shared by the serial, sharded, and batched-sweep paths; ``step`` may carry
    leading batch axes on state/thresholds (e.g. the vmapped run axis of
    ``core.sweep``) as long as it accepts the same positional signature as
    ``make_generation_step``'s result.
    """
    def body(state, gen_idx):
        state = step(state, thresholds, in_planes, golden_vals, gen_idx)
        out = (state.parent_power / golden_power, state.parent_metrics,
               state.parent_fit)
        return state, out

    return jax.lax.scan(body, state0, jnp.arange(generations))


@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def evolve(spec: CGPSpec, cfg: EvolveConfig, golden: Genome,
           thresholds: jax.Array, in_planes: jax.Array,
           golden_vals: jax.Array, golden_power: jax.Array,
           key: jax.Array) -> EvolveResult:
    """Single-island paper-faithful run (jit; scan over generations)."""
    step = make_generation_step(spec, cfg)
    state0 = init_state(spec, cfg, golden, thresholds, in_planes, golden_vals,
                        key)
    state, (hp, hm, hf) = scan_generations(step, state0, thresholds,
                                           in_planes, golden_vals,
                                           golden_power, cfg.generations)
    return EvolveResult(state.parent, state.best, state.best_fit, hp, hm, hf)


# --------------------------------------------------------------------------
# Distributed evolution (shard_map over the production mesh)
# --------------------------------------------------------------------------

def evolve_sharded(mesh, spec: CGPSpec, cfg: EvolveConfig, golden: Genome,
                   thresholds_per_pod: jax.Array, golden_power: jax.Array,
                   *, data_axis: str = "data", model_axis: str = "model",
                   pod_axis: str | None = None):
    """Build the shard_map'd multi-island evolve function (DESIGN.md §2.2).

    This is the ISLAND formulation of the distributed search: one (1+λ) run
    per ``data``-axis slice with periodic best-parent migration
    (``_migrate``), each run's candidate evaluation input-space-sharded over
    ``model`` (metric partials / popcounts psum across it, see
    ``metrics.combine_partials``), and — when ``pod_axis`` is given — one
    constraint configuration per pod slice.  For the paper's constraint×seed
    GRID at production scale, use the pod-sharded batched sweep instead
    (``core.sweep.run_sweep_batched`` with ``SweepConfig.n_pods``, DESIGN.md
    §6): there the pod axis partitions whole chunks of independent runs and
    needs no cross-pod collectives at all.

    Args:
      mesh: the active device mesh; must carry ``data_axis`` and
        ``model_axis`` (and ``pod_axis`` when given).  The production shapes
        are built by ``launch.mesh``.
      spec/cfg/golden/golden_power: the problem, as in ``evolve`` —
        ``cfg.migrate_every`` sets the island migration period.
      thresholds_per_pod: ``(n_pod_cfgs, N_METRICS)`` threshold matrix,
        sharded over ``pod_axis`` so each pod slice evolves under its own
        combined-constraint vector — or ``(1, N_METRICS)`` replicated when
        ``pod_axis`` is None (every island shares one constraint).
      data_axis / model_axis / pod_axis: physical mesh-axis names (the
        logical mapping lives in ``parallel.ctx.LOGICAL``).

    Returns:
      fn(thresholds, keys, in_planes, golden_vals) — shard_map'd over
      ``mesh``; ``keys`` is ``(n_islands,)`` PRNG keys sharded over
      ``data_axis`` (see ``make_island_keys``), ``in_planes``/``golden_vals``
      the input cube sharded over ``model_axis`` on the word/value axis.
      Returns per-island stacked (parent, best, best_fit, hist_power_rel,
      hist_metrics, hist_fit), gathered over ``data_axis``.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    axes = [a for a in (pod_axis, data_axis, model_axis) if a is not None]

    def island_run(thresholds, key, in_planes, golden_vals):
        # runs on ONE (pod, data, model) shard; model axis splits the cube
        thresholds = thresholds[0]  # local shard is (1, N_METRICS)
        step = make_generation_step(spec, cfg, axis_name=model_axis,
                                    island_axis=data_axis)
        state0 = init_state(spec, cfg, golden, thresholds, in_planes,
                            golden_vals, key[0], axis_name=model_axis)
        state, (hp, hm, hf) = scan_generations(step, state0, thresholds,
                                               in_planes, golden_vals,
                                               golden_power, cfg.generations)
        # re-add leading axes stripped by shard_map (1 island per shard)
        expand = lambda t: jax.tree.map(lambda x: x[None], t)
        return (expand(state.parent), expand(state.best),
                state.best_fit[None], hp[None], hm[None], hf[None])

    pod = pod_axis if pod_axis is not None else None
    in_specs = (P(pod, None),            # thresholds (pods, N_METRICS)
                P(data_axis),            # per-island keys
                P(None, model_axis),     # input planes (n_i, W)
                P(model_axis))           # golden values (2^n,)
    out_leaf = P(data_axis)
    out_specs = (jax.tree.map(lambda _: out_leaf, golden),
                 jax.tree.map(lambda _: out_leaf, golden),
                 out_leaf, out_leaf, out_leaf, out_leaf)

    fn = shard_map(island_run, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    return fn


def make_island_keys(seed: int, n_islands: int) -> jax.Array:
    return jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(seed), i))(jnp.arange(n_islands))

"""EvoApproxLib-style circuit library (paper Sec. I / Fig. 14).

Evolved circuits are stored as JSON records (genome + full characterization)
so applications can select "the best circuit under constraint X" exactly the
way the paper describes using EvoApproxLib — and so the approximate-matmul
deployment path (models/quant.py) can load a multiplier LUT by name.
"""
from __future__ import annotations

import json
import os
from typing import Iterable

import numpy as np

from repro.core import metrics as M
from repro.core.genome import CGPSpec, Genome
from repro.core.search import CircuitRecord


def save_library(records: Iterable[CircuitRecord], path: str) -> None:
    data = []
    for r in records:
        data.append({
            "nodes": np.asarray(r.genome_nodes).tolist(),
            "outs": np.asarray(r.genome_outs).tolist(),
            "metrics": {n: float(v) for n, v in
                        zip(M.METRIC_NAMES, r.metrics)},
            "power_rel": r.power_rel,
            "constraint": r.constraint,
            "seed": r.seed,
            "feasible": r.feasible,
            "error_mean": r.error_mean,
            "error_std": r.error_std,
        })
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f)


def load_library(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def record_to_genome(rec: dict) -> Genome:
    import jax.numpy as jnp
    return Genome(jnp.asarray(np.array(rec["nodes"], dtype=np.int32)),
                  jnp.asarray(np.array(rec["outs"], dtype=np.int32)))


def select_best(records: list[dict], **max_metrics: float) -> dict | None:
    """Pick the lowest-power feasible circuit under the given metric caps.

    Example: ``select_best(lib, mae=0.1, er=50.0)``.
    """
    best, best_p = None, float("inf")
    for r in records:
        if not r["feasible"]:
            continue
        ok = all(r["metrics"][k] <= v if k not in ("acc0", "gauss")
                 else r["metrics"][k] >= 1.0
                 for k, v in max_metrics.items())
        if ok and r["power_rel"] < best_p:
            best, best_p = r, r["power_rel"]
    return best


def multiplier_lut(genome: Genome, spec: CGPSpec) -> np.ndarray:
    """(2^w, 2^w) int32 product table of an evolved multiplier.

    This is the deployment artifact consumed by ``models/quant.py`` /
    ``kernels/lut_matmul.py`` — on silicon the circuit IS the multiplier; on
    TPU we emulate it exactly through this LUT.
    """
    from repro.core.simulate import simulate_values
    w = spec.n_i // 2
    # sub-word cubes (n_i < 5) come back tiled to 32 lanes by whole-cube
    # replication (simulate.input_planes); the first 2^n_i lanes are the
    # cube in index order
    vals = np.asarray(simulate_values(genome, spec))[:1 << spec.n_i]
    return vals.reshape(1 << w, 1 << w).T.copy()  # [a, b] -> a*b approx

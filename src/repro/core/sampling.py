"""Sampled + distribution-weighted input generation (DESIGN.md §9).

Every evaluation path historically assumed the full 2^(2w) input cube, which
dies around width 10-12 (width 16 = 4.3e9 rows/genome) — exactly where real
datapaths live.  This module breaks that wall: it draws a deterministic
SAMPLE of operand pairs from a chosen input distribution and packs it into
the same ``(n_i, W)`` bit-plane / ``(W*32,)`` golden-value contract the
exhaustive cube uses, so everything downstream (the fused Pallas kernel, the
cube-shard ``psum/pmax`` combine of DESIGN.md §6, the batched sweep engine)
runs unchanged — integer metric partials stay EXACT on the sample, and the
appended second-moment partials turn into standard errors per metric
(``metrics.metric_stderr``).

Determinism contract: operands come from counter-based PRNG streams (the
``data.pipeline._hash_u32`` xorshift-mult mix) indexed by
``(sample_seed, stream, row)`` — no stateful RNG, so a sample is a pure
function of ``(width, sample_size, input_dist, sample_seed)``.  Checkpoint
resume, pod sharding and the phenotype-dedup cache all key on
``stream_fingerprint`` of that tuple: replaying a sweep re-materializes the
exact same rows, and cache entries can never leak across sample streams.

Distributions (the ``input_dist`` axis; arXiv 1903.04188 motivates scoring
circuits on the traffic they will actually see):

  * ``"uniform"``   — each operand i.i.d. uniform over [0, 2^w);
  * ``"gaussian"``  — Box-Muller on two hash streams, mean centered at
    (2^w - 1)/2, σ = 2^w/6 (±3σ spans the range), clipped to [0, 2^w);
  * ``"empirical"`` — operands drawn by inverse-CDF from a histogram
    captured off the ``data.pipeline`` synthetic activation/token stream
    (``empirical_histogram``), i.e. a Zipf-ish low-value-heavy workload.

Sample sizes round UP so the packed word count is a power of two: the fused
kernel requires ``W % min(block_words, W) == 0``, and a pow2 word axis also
splits evenly over any pow2 cube-shard mesh.  ``effective_sample_size``
reports the materialized row count.
"""
from __future__ import annotations

import hashlib
import json

import numpy as np

from repro.data.pipeline import DataConfig, _hash_u32, synth_batch

INPUT_DISTS = ("uniform", "gaussian", "empirical")

# stream tags keep the operand-a / operand-b / auxiliary hash streams
# disjoint inside one (sample_seed, row) counter space
_STREAM_A, _STREAM_B, _STREAM_A2, _STREAM_B2 = range(4)


def effective_sample_size(sample_size: int) -> int:
    """Materialized rows: sample_size rounded up to a pow2 multiple of 32."""
    if sample_size < 1:
        raise ValueError(f"sample_size must be >= 1, got {sample_size}")
    n_words = max((sample_size + 31) // 32, 1)
    n_words = 1 << (n_words - 1).bit_length()
    return n_words * 32


def _stream_u32(seed: int, stream: int, n: int) -> np.ndarray:
    """(n,) uint32 from the counter-based hash: lane (seed, stream, row)."""
    base = (np.uint64(seed) << np.uint64(34)) \
        + (np.uint64(stream) << np.uint64(32))
    return _hash_u32(base + np.arange(n, dtype=np.uint64))


def _uniform_operand(seed: int, stream: int, n: int, width: int) -> np.ndarray:
    return (_stream_u32(seed, stream, n) >> np.uint32(32 - width)).astype(
        np.int64)


def _gaussian_operand(seed: int, stream: int, stream2: int, n: int,
                      width: int) -> np.ndarray:
    """Box-Muller on two u32 streams -> N(center, (2^w/6)^2), clipped."""
    u1 = (_stream_u32(seed, stream, n).astype(np.float64) + 0.5) / 2**32
    u2 = (_stream_u32(seed, stream2, n).astype(np.float64) + 0.5) / 2**32
    z = np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)
    hi = (1 << width) - 1
    vals = np.rint(hi / 2.0 + z * ((1 << width) / 6.0))
    return np.clip(vals, 0, hi).astype(np.int64)


def empirical_histogram(width: int, seed: int = 0,
                        n_batches: int = 4) -> np.ndarray:
    """(2^w,) operand-value counts captured from the data pipeline.

    The synthetic corpus's Zipf-ish token stream stands in for real
    activation traffic: token ids fold into the operand range (mod 2^w), so
    low values dominate like quantized NN activations do.  Deterministic in
    ``(width, seed, n_batches)`` — the pipeline itself is counter-based.
    """
    n_vals = 1 << width
    cfg = DataConfig(vocab=32000, seq_len=1024, global_batch=8, seed=seed)
    counts = np.zeros(n_vals, np.int64)
    for step in range(n_batches):
        toks = synth_batch(cfg, step)["tokens"].reshape(-1)
        counts += np.bincount(toks % n_vals, minlength=n_vals)
    return counts


def _empirical_operand(seed: int, stream: int, n: int, width: int,
                       hist: np.ndarray) -> np.ndarray:
    """Inverse-CDF draw from a (2^w,) histogram via one u32 stream."""
    if hist.shape != (1 << width,):
        raise ValueError(f"histogram shape {hist.shape} != {(1 << width,)}")
    total = int(hist.sum())
    if total <= 0:
        raise ValueError("empirical histogram is empty")
    cdf = np.cumsum(hist.astype(np.float64)) / total
    u = (_stream_u32(seed, stream, n).astype(np.float64) + 0.5) / 2**32
    return np.searchsorted(cdf, u, side="left").clip(0, (1 << width) - 1) \
        .astype(np.int64)


def sampled_operands(width: int, sample_size: int, input_dist: str,
                     sample_seed: int = 0,
                     empirical_hist: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic (a, b) operand sample, each (effective_sample_size,).

    Pure function of its arguments (plus the histogram for
    ``"empirical"``, itself deterministic via ``empirical_histogram``).
    Operands a and b draw from disjoint hash streams, so they are
    independent even at equal row indices.
    """
    if input_dist not in INPUT_DISTS:
        raise ValueError(
            f"input_dist must be one of {INPUT_DISTS}, got {input_dist!r}")
    n = effective_sample_size(sample_size)
    if input_dist == "uniform":
        a = _uniform_operand(sample_seed, _STREAM_A, n, width)
        b = _uniform_operand(sample_seed, _STREAM_B, n, width)
    elif input_dist == "gaussian":
        a = _gaussian_operand(sample_seed, _STREAM_A, _STREAM_A2, n, width)
        b = _gaussian_operand(sample_seed, _STREAM_B, _STREAM_B2, n, width)
    else:  # empirical
        if empirical_hist is None:
            empirical_hist = empirical_histogram(width, seed=sample_seed)
        a = _empirical_operand(sample_seed, _STREAM_A, n, width,
                               empirical_hist)
        b = _empirical_operand(sample_seed, _STREAM_B, n, width,
                               empirical_hist)
    return a, b


def pack_sample_planes(a: np.ndarray, b: np.ndarray,
                       width: int) -> np.ndarray:
    """(2*width, n_rows/32) int32 bit-planes of sampled operand rows.

    Mirrors ``simulate.input_planes_np`` packing with the exhaustive index
    ``x = a + (b << width)``: bit ``l`` of word ``w`` in plane ``i`` is bit
    ``i`` of row ``32*w + l``'s x — planes [0, w) are operand a's bits,
    planes [w, 2w) operand b's.
    """
    if a.shape != b.shape or a.ndim != 1 or a.size % 32:
        raise ValueError(f"need equal 1-D operands, length % 32 == 0; got "
                         f"{a.shape} / {b.shape}")
    xs = (a.astype(np.uint64) | (b.astype(np.uint64) << np.uint64(width)))
    planes = []
    for i in range(2 * width):
        bits = ((xs >> np.uint64(i)) & np.uint64(1)).astype(np.uint32)
        words = bits.reshape(-1, 32)
        packed = (words << np.arange(32, dtype=np.uint32)[None, :]).sum(
            axis=1, dtype=np.uint32)
        planes.append(packed)
    return np.stack(planes).astype(np.int32)  # two's complement reinterpret


def sampled_golden_values(a: np.ndarray, b: np.ndarray,
                          kind: str) -> np.ndarray:
    """int32 exact golden outputs on the sample rows (mirrors
    ``golden.golden_values`` semantics, sample-indexed instead of
    cube-indexed)."""
    if kind == "mul":
        return (a * b).astype(np.int32)
    if kind == "add":
        return (a + b).astype(np.int32)
    raise ValueError(kind)


def sample_problem(width: int, kind: str, sample_size: int, input_dist: str,
                   sample_seed: int = 0
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(in_planes (2w, W), golden_vals (W*32,)) for one sample stream —
    drop-in for the exhaustive ``(input_planes, golden_values)`` pair."""
    a, b = sampled_operands(width, sample_size, input_dist, sample_seed)
    return pack_sample_planes(a, b, width), sampled_golden_values(a, b, kind)


def stream_fingerprint(width: int, sample_size: int, input_dist: str,
                       sample_seed: int = 0) -> str:
    """Identity of one sample stream (hex digest).

    Everything that changes the materialized rows is in here — incorporate
    it into any cache/checkpoint key whose values depend on WHICH inputs a
    circuit was measured on (the phenotype-dedup cache scope, the sweep grid
    fingerprint).  ``sample_size`` enters as its effective (rounded) value,
    so two nominal sizes that materialize identical rows share entries.
    """
    ident = {
        "width": width,
        "effective_sample_size": effective_sample_size(sample_size),
        "input_dist": input_dist,
        "sample_seed": sample_seed,
        "stream": "hash_u32/v1",
    }
    return hashlib.sha256(
        json.dumps(ident, sort_keys=True).encode()).hexdigest()

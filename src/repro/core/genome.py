"""CGP genome representation (paper Sec. III-A).

A candidate circuit with ``n_i`` primary inputs, ``n_o`` primary outputs and
``n_n`` two-input nodes is encoded exactly as in the paper: each node is
``(in0, in1, func)`` where the fan-in indices address either a primary input
(``< n_i``) or an *earlier* node (``n_i + k`` for node ``k``), i.e. full
levels-back (L = n_n), which forbids feedback by construction.  The genome is
kept as two int32 arrays so it vmaps/shards trivially:

    nodes : (n_n, 3) int32
    outs  : (n_o,)   int32

All functions here are jit/vmap-safe unless suffixed ``_np``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gates


class Genome(NamedTuple):
    """A CGP genome; leaves may carry leading batch dims under vmap."""
    nodes: jax.Array  # (n_n, 3) int32 — (in0, in1, func)
    outs: jax.Array   # (n_o,)  int32


@dataclasses.dataclass(frozen=True)
class CGPSpec:
    """Static CGP problem shape (hashable: usable as a jit static arg)."""
    n_i: int          # primary inputs
    n_o: int          # primary outputs
    n_n: int = 400    # nodes (paper: 400)
    n_funcs: int = gates.N_FUNCS

    @property
    def n_wires(self) -> int:
        return self.n_i + self.n_n

    @property
    def n_genes(self) -> int:
        return self.n_n * 3 + self.n_o

    @property
    def n_inputs_total(self) -> int:
        """Number of exhaustive input combinations 2^n_i."""
        return 1 << self.n_i

    @property
    def n_words(self) -> int:
        """Packed 32-bit words needed to cover the input cube."""
        return max(1, self.n_inputs_total // 32)


def max_fanin_index(spec: CGPSpec) -> np.ndarray:
    """Exclusive upper bound of a legal fan-in index for each node position."""
    return spec.n_i + np.arange(spec.n_n, dtype=np.int32)


def random_genome(key: jax.Array, spec: CGPSpec) -> Genome:
    """Uniform random (legal, feed-forward) genome."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    hi = jnp.asarray(max_fanin_index(spec))  # (n_n,)
    in0 = jax.random.randint(k1, (spec.n_n,), 0, hi)
    in1 = jax.random.randint(k2, (spec.n_n,), 0, hi)
    func = jax.random.randint(k3, (spec.n_n,), 0, spec.n_funcs)
    outs = jax.random.randint(k4, (spec.n_o,), 0, spec.n_wires)
    return Genome(jnp.stack([in0, in1, func], axis=-1).astype(jnp.int32),
                  outs.astype(jnp.int32))


def validate_genome(genome: Genome, spec: CGPSpec) -> bool:
    """Host-side legality check (feed-forward indices in range)."""
    nodes = np.asarray(genome.nodes)
    outs = np.asarray(genome.outs)
    if nodes.shape != (spec.n_n, 3) or outs.shape != (spec.n_o,):
        return False
    hi = max_fanin_index(spec)
    ok = (nodes[:, 0] >= 0).all() and (nodes[:, 1] >= 0).all()
    ok &= (nodes[:, 0] < hi).all() and (nodes[:, 1] < hi).all()
    ok &= (0 <= nodes[:, 2]).all() and (nodes[:, 2] < spec.n_funcs).all()
    ok &= (outs >= 0).all() and (outs < spec.n_wires).all()
    return bool(ok)


def active_mask(genome: Genome, spec: CGPSpec) -> jax.Array:
    """Boolean (n_wires,) mask of wires reachable from the primary outputs.

    Classic CGP "active node" computation (the paper's redundant encoding means
    most of the 400 nodes are usually inactive).  Because fan-ins always point
    backwards, a single reverse sweep over the node array suffices; implemented
    as ``lax.scan`` so it stays jit/vmap friendly.
    """
    n_i, n_n = spec.n_i, spec.n_n
    active0 = jnp.zeros((spec.n_wires,), dtype=bool).at[genome.outs].set(True)
    one_input = jnp.asarray(gates.ONE_INPUT)

    def step(active, k):
        # walk nodes from last to first
        idx = n_n - 1 - k
        node = genome.nodes[idx]
        is_act = active[n_i + idx]
        uses_b = one_input[node[2]] == 0
        active = active.at[node[0]].set(active[node[0]] | is_act)
        active = active.at[node[1]].set(active[node[1]] | (is_act & uses_b))
        return active, None

    active, _ = jax.lax.scan(step, active0, jnp.arange(n_n))
    return active


def active_node_count(genome: Genome, spec: CGPSpec) -> jax.Array:
    return active_mask(genome, spec)[spec.n_i:].sum()


def critical_path_ps(genome: Genome, spec: CGPSpec) -> jax.Array:
    """Longest-path delay (ps) over *active* wires using per-gate delays."""
    delay_tab = jnp.asarray(gates.DELAY_PS)
    one_input = jnp.asarray(gates.ONE_INPUT)
    act = active_mask(genome, spec)
    depth0 = jnp.zeros((spec.n_wires,), dtype=jnp.float32)

    def step(depth, k):
        node = genome.nodes[k]
        d_in0 = depth[node[0]]
        d_in1 = jnp.where(one_input[node[2]] == 1, 0.0, depth[node[1]])
        d = jnp.maximum(d_in0, d_in1) + delay_tab[node[2]]
        d = jnp.where(act[spec.n_i + k], d, 0.0)
        return depth.at[spec.n_i + k].set(d), None

    depth, _ = jax.lax.scan(step, depth0, jnp.arange(spec.n_n))
    return jnp.max(depth[genome.outs])


# --------------------------------------------------------------------------
# Canonical phenotype form (DESIGN.md §8)
# --------------------------------------------------------------------------
#
# The paper's CGP encoding is deliberately redundant: most of the 400 nodes
# are inactive, so many genotypes share one *phenotype* — the subgraph of
# active nodes actually reachable from the primary outputs.  Everything a
# candidate evaluation returns (error metrics AND the activity-masked
# power/area model) is a function of that subgraph alone, which makes the
# canonical form below a sound cache key for evaluation results:
#
#   * active nodes are COMPACTED to the front of the node array in their
#     original (= topological: fan-ins always point backwards) order and
#     every fan-in / output gene is renumbered accordingly;
#   * the unused second fan-in of a one-input gate is zeroed (it never
#     affects simulation, but would otherwise split identical phenotypes);
#   * inactive genes are dropped entirely (the tail of the canonical array
#     is zero and excluded from the digest).
#
# Two genotypes map to the same canonical form iff their active subgraphs
# are gate-for-gate identical (commutative input swaps are deliberately NOT
# folded — a swapped gate is a different, if equivalent, subgraph).  The
# digest is a 16-byte BLAKE2b over the canonical genes, so accidental
# collisions are vanishingly unlikely (~2^-64 at billions of entries).

PHENOTYPE_DIGEST_SIZE = 16  # bytes of BLAKE2b digest per phenotype


def active_mask_np(nodes: np.ndarray, outs: np.ndarray,
                   spec: CGPSpec) -> np.ndarray:
    """Batched host-side active mask: (R, n_wires) bool.

    NumPy twin of ``active_mask`` for the dedup cache's host-side
    canonicalization (one reverse sweep, vectorized over the population).
    """
    nodes = np.asarray(nodes)
    outs = np.asarray(outs)
    R = nodes.shape[0]
    n_i = spec.n_i
    one_input = gates.ONE_INPUT
    act = np.zeros((R, spec.n_wires), dtype=bool)
    act[np.arange(R)[:, None], outs] = True
    rows = np.arange(R)
    for k in range(spec.n_n - 1, -1, -1):
        is_act = act[:, n_i + k]
        uses_b = is_act & (one_input[nodes[:, k, 2]] == 0)
        act[rows, nodes[:, k, 0]] |= is_act
        act[rows, nodes[:, k, 1]] |= uses_b
    return act


def canonicalize_phenotypes_np(nodes: np.ndarray, outs: np.ndarray,
                               spec: CGPSpec
                               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Canonical active-subgraph form of a stacked population.

    Args:
      nodes: (R, n_n, 3) int32; outs: (R, n_o) int32 (host arrays).

    Returns:
      (canon_nodes (R, n_n, 3), canon_outs (R, n_o), n_active (R,)) — per
      genome, the first ``n_active[r]`` rows of ``canon_nodes[r]`` hold the
      active subgraph in topological order with renumbered fan-ins and
      zeroed unary second fan-ins; the tail rows are zero.
    """
    nodes = np.asarray(nodes)
    outs = np.asarray(outs)
    R = nodes.shape[0]
    n_i, n_n = spec.n_i, spec.n_n
    act = active_mask_np(nodes, outs, spec)
    node_act = act[:, n_i:]                       # (R, n_n)
    new_idx = np.cumsum(node_act, axis=1, dtype=np.int32) - 1
    n_active = node_act.sum(axis=1).astype(np.int32)

    def remap(w):  # wire index -> canonical wire index, rows aligned
        node_ref = w >= n_i
        k = np.clip(w - n_i, 0, n_n - 1)
        return np.where(node_ref,
                        n_i + np.take_along_axis(new_idx, k, axis=1), w)

    func = nodes[:, :, 2]
    unary = gates.ONE_INPUT[func] == 1
    m0 = remap(nodes[:, :, 0])
    m1 = remap(np.where(unary, 0, nodes[:, :, 1]))

    canon = np.zeros((R, n_n, 3), np.int32)
    r_idx, k_idx = np.nonzero(node_act)
    pos = new_idx[r_idx, k_idx]
    canon[r_idx, pos, 0] = m0[r_idx, k_idx]
    canon[r_idx, pos, 1] = m1[r_idx, k_idx]
    canon[r_idx, pos, 2] = func[r_idx, k_idx]
    canon_outs = remap(outs).astype(np.int32)
    return canon, canon_outs, n_active


def phenotype_digests(nodes: np.ndarray, outs: np.ndarray,
                      spec: CGPSpec) -> list[bytes]:
    """Stable per-genome phenotype digests of a stacked population.

    Identical for genotypes with the same active subgraph; used as the
    dedup-cache key (``core.evalcache``).  Host-side by design — the dedup
    path runs between jit segments (DESIGN.md §8).
    """
    canon, canon_outs, n_active = canonicalize_phenotypes_np(nodes, outs,
                                                             spec)
    digests = []
    for r in range(canon.shape[0]):
        na = int(n_active[r])
        h = hashlib.blake2b(digest_size=PHENOTYPE_DIGEST_SIZE)
        h.update(na.to_bytes(4, "little"))
        h.update(canon[r, :na].tobytes())
        h.update(canon_outs[r].tobytes())
        digests.append(h.digest())
    return digests


def phenotype_digest(genome: Genome, spec: CGPSpec) -> bytes:
    """Single-genome convenience wrapper around ``phenotype_digests``."""
    return phenotype_digests(np.asarray(genome.nodes)[None],
                             np.asarray(genome.outs)[None], spec)[0]


def genome_to_flat(genome: Genome) -> jax.Array:
    """Flatten to the paper's integer string (n_n*(n_a+1)+n_o ints)."""
    return jnp.concatenate([genome.nodes.reshape(-1), genome.outs])


def flat_to_genome(flat: jax.Array, spec: CGPSpec) -> Genome:
    nodes = flat[: spec.n_n * 3].reshape(spec.n_n, 3)
    outs = flat[spec.n_n * 3:]
    return Genome(nodes, outs)

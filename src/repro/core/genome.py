"""CGP genome representation (paper Sec. III-A).

A candidate circuit with ``n_i`` primary inputs, ``n_o`` primary outputs and
``n_n`` two-input nodes is encoded exactly as in the paper: each node is
``(in0, in1, func)`` where the fan-in indices address either a primary input
(``< n_i``) or an *earlier* node (``n_i + k`` for node ``k``), i.e. full
levels-back (L = n_n), which forbids feedback by construction.  The genome is
kept as two int32 arrays so it vmaps/shards trivially:

    nodes : (n_n, 3) int32
    outs  : (n_o,)   int32

All functions here are jit/vmap-safe unless suffixed ``_np``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gates


class Genome(NamedTuple):
    """A CGP genome; leaves may carry leading batch dims under vmap."""
    nodes: jax.Array  # (n_n, 3) int32 — (in0, in1, func)
    outs: jax.Array   # (n_o,)  int32


@dataclasses.dataclass(frozen=True)
class CGPSpec:
    """Static CGP problem shape (hashable: usable as a jit static arg)."""
    n_i: int          # primary inputs
    n_o: int          # primary outputs
    n_n: int = 400    # nodes (paper: 400)
    n_funcs: int = gates.N_FUNCS

    @property
    def n_wires(self) -> int:
        return self.n_i + self.n_n

    @property
    def n_genes(self) -> int:
        return self.n_n * 3 + self.n_o

    @property
    def n_inputs_total(self) -> int:
        """Number of exhaustive input combinations 2^n_i."""
        return 1 << self.n_i

    @property
    def n_words(self) -> int:
        """Packed 32-bit words needed to cover the input cube."""
        return max(1, self.n_inputs_total // 32)


def max_fanin_index(spec: CGPSpec) -> np.ndarray:
    """Exclusive upper bound of a legal fan-in index for each node position."""
    return spec.n_i + np.arange(spec.n_n, dtype=np.int32)


def random_genome(key: jax.Array, spec: CGPSpec) -> Genome:
    """Uniform random (legal, feed-forward) genome."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    hi = jnp.asarray(max_fanin_index(spec))  # (n_n,)
    in0 = jax.random.randint(k1, (spec.n_n,), 0, hi)
    in1 = jax.random.randint(k2, (spec.n_n,), 0, hi)
    func = jax.random.randint(k3, (spec.n_n,), 0, spec.n_funcs)
    outs = jax.random.randint(k4, (spec.n_o,), 0, spec.n_wires)
    return Genome(jnp.stack([in0, in1, func], axis=-1).astype(jnp.int32),
                  outs.astype(jnp.int32))


def validate_genome(genome: Genome, spec: CGPSpec) -> bool:
    """Host-side legality check (feed-forward indices in range)."""
    nodes = np.asarray(genome.nodes)
    outs = np.asarray(genome.outs)
    if nodes.shape != (spec.n_n, 3) or outs.shape != (spec.n_o,):
        return False
    hi = max_fanin_index(spec)
    ok = (nodes[:, 0] >= 0).all() and (nodes[:, 1] >= 0).all()
    ok &= (nodes[:, 0] < hi).all() and (nodes[:, 1] < hi).all()
    ok &= (0 <= nodes[:, 2]).all() and (nodes[:, 2] < spec.n_funcs).all()
    ok &= (outs >= 0).all() and (outs < spec.n_wires).all()
    return bool(ok)


def active_mask(genome: Genome, spec: CGPSpec) -> jax.Array:
    """Boolean (n_wires,) mask of wires reachable from the primary outputs.

    Classic CGP "active node" computation (the paper's redundant encoding means
    most of the 400 nodes are usually inactive).  Because fan-ins always point
    backwards, a single reverse sweep over the node array suffices; implemented
    as ``lax.scan`` so it stays jit/vmap friendly.
    """
    n_i, n_n = spec.n_i, spec.n_n
    active0 = jnp.zeros((spec.n_wires,), dtype=bool).at[genome.outs].set(True)
    one_input = jnp.asarray(gates.ONE_INPUT)

    def step(active, k):
        # walk nodes from last to first
        idx = n_n - 1 - k
        node = genome.nodes[idx]
        is_act = active[n_i + idx]
        uses_b = one_input[node[2]] == 0
        active = active.at[node[0]].set(active[node[0]] | is_act)
        active = active.at[node[1]].set(active[node[1]] | (is_act & uses_b))
        return active, None

    active, _ = jax.lax.scan(step, active0, jnp.arange(n_n))
    return active


def active_node_count(genome: Genome, spec: CGPSpec) -> jax.Array:
    return active_mask(genome, spec)[spec.n_i:].sum()


def critical_path_ps(genome: Genome, spec: CGPSpec) -> jax.Array:
    """Longest-path delay (ps) over *active* wires using per-gate delays."""
    delay_tab = jnp.asarray(gates.DELAY_PS)
    one_input = jnp.asarray(gates.ONE_INPUT)
    act = active_mask(genome, spec)
    depth0 = jnp.zeros((spec.n_wires,), dtype=jnp.float32)

    def step(depth, k):
        node = genome.nodes[k]
        d_in0 = depth[node[0]]
        d_in1 = jnp.where(one_input[node[2]] == 1, 0.0, depth[node[1]])
        d = jnp.maximum(d_in0, d_in1) + delay_tab[node[2]]
        d = jnp.where(act[spec.n_i + k], d, 0.0)
        return depth.at[spec.n_i + k].set(d), None

    depth, _ = jax.lax.scan(step, depth0, jnp.arange(spec.n_n))
    return jnp.max(depth[genome.outs])


def genome_to_flat(genome: Genome) -> jax.Array:
    """Flatten to the paper's integer string (n_n*(n_a+1)+n_o ints)."""
    return jnp.concatenate([genome.nodes.reshape(-1), genome.outs])


def flat_to_genome(flat: jax.Array, spec: CGPSpec) -> Genome:
    nodes = flat[: spec.n_n * 3].reshape(spec.n_n, 3)
    outs = flat[spec.n_n * 3:]
    return Genome(nodes, outs)

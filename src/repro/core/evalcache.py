"""Phenotype-keyed evaluation cache (DESIGN.md §8).

CGP point mutation is mostly neutral, so a large fraction of every
(chunk × λ) population shares an identical active subgraph with its parent
or a sibling — yet the batched engine used to re-simulate every copy against
the whole 2^(2w) input cube each generation.  This module holds the
host-side LRU behind the dedup evaluation path (``core.sweep``):

  * keys are ``(phenotype digest, grid fingerprint, gauss_sigma)`` tuples —
    the digest identifies the active subgraph (``genome.phenotype_digests``),
    the fingerprint pins the problem (golden circuit, cube, metric budget)
    and σ pins the Gauss-histogram bin edges, so an entry can never leak
    across problems or σ-groups;
  * values are the phenotype-invariant projection of a candidate evaluation:
    the finalized ``(metric_vec, power)`` pair.  Raw popcounts / per-wire
    signal probabilities are deliberately NOT cached — they are indexed by
    raw node position, which differs between genotypes of one phenotype;
    the activity-masked power scalar is identical for all of them
    (inactive positions contribute exactly 0.0 to the float32 sums in
    ``power.circuit_cost_from_probs``, and the active terms appear in the
    same topological order), which is what makes the scatter bit-exact;
  * the size bound is entry-count based (one entry ≈ digest + 8 float32s,
    so the default 65536 bound stays in the low MB) with strict
    least-recently-used eviction, and every lookup/insert/evict is counted
    so the sweep can report a measured hit rate (``CacheStats``).

The cache is execution-state only: it never changes results (bit-identity
with the uncached path is differentially tested), so dropping, bounding or
clearing it is always safe.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Hashable


@dataclasses.dataclass
class CacheStats:
    """Counters of one dedup-cache lifetime (one sweep call).

    ``candidates`` counts every offspring the dedup path saw; ``evaluated``
    counts the unique phenotypes that actually reached the kernel.  The
    headline ``hit_rate`` is the fraction of candidate evaluations avoided —
    by a cross-generation LRU hit OR by a within-generation duplicate.
    """
    candidates: int = 0     # offspring seen by the dedup path
    evaluated: int = 0      # unique phenotypes dispatched to the kernel
    lru_hits: int = 0       # avoided by a cross-generation cache entry
    dup_hits: int = 0       # avoided by a duplicate inside one generation
    inserts: int = 0        # NEW keys stored (invariant: inserts ==
                            # live entries + evictions)
    overwrites: int = 0     # puts that replaced an existing key's value
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        if self.candidates == 0:
            return 0.0
        return 1.0 - self.evaluated / self.candidates

    def as_dict(self) -> dict:
        return {
            "candidates": self.candidates,
            "evaluated": self.evaluated,
            "lru_hits": self.lru_hits,
            "dup_hits": self.dup_hits,
            "inserts": self.inserts,
            "overwrites": self.overwrites,
            "evictions": self.evictions,
            "hit_rate": self.hit_rate,
        }


class PhenotypeLRU:
    """Bounded host-side LRU over phenotype-keyed evaluation results."""

    def __init__(self, max_entries: int = 1 << 16):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = int(max_entries)
        self._store: OrderedDict[Hashable, object] = OrderedDict()
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._store

    def get(self, key: Hashable):
        """Value for ``key`` (refreshed to most-recently-used) or None."""
        val = self._store.get(key)
        if val is not None:
            self._store.move_to_end(key)
        return val

    def put(self, key: Hashable, value) -> None:
        if key in self._store:
            self._store.move_to_end(key)
            self.stats.overwrites += 1
        else:
            self.stats.inserts += 1
        self._store[key] = value
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)
            self.stats.evictions += 1

    def clear(self) -> None:
        self._store.clear()

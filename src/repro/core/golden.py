"""Exact "golden" circuit builders (paper Sec. IV).

The paper's golden circuit is the 8x8 array multiplier produced by yosys for
the Verilog ``*`` operator.  We build the structurally equivalent textbook
array multiplier (AND partial products + half/full-adder reduction rows) — the
same netlist family yosys emits for small operand widths — directly as a CGP
genome, plus ripple-carry adders for the "structurally simpler circuits"
remark in Sec. IV.  Exactness of every builder is asserted against NumPy in
tests for widths 2..8.
"""
from __future__ import annotations

import numpy as np

from repro.core import gates
from repro.core.genome import CGPSpec, Genome


class NetBuilder:
    """Builds a feed-forward netlist and pads it into a fixed-size genome."""

    def __init__(self, n_i: int, n_o: int):
        self.n_i = n_i
        self.n_o = n_o
        self.nodes: list[tuple[int, int, int]] = []

    def gate(self, func: int, a: int, b: int | None = None) -> int:
        if b is None:
            b = a
        idx = self.n_i + len(self.nodes)
        assert a < idx and b < idx, "feed-forward violation"
        self.nodes.append((a, b, func))
        return idx

    # convenience wrappers -------------------------------------------------
    def and_(self, a, b):  return self.gate(gates.AND, a, b)
    def or_(self, a, b):   return self.gate(gates.OR, a, b)
    def xor_(self, a, b):  return self.gate(gates.XOR, a, b)
    def buf(self, a):      return self.gate(gates.BUF, a)

    def half_adder(self, a: int, b: int) -> tuple[int, int]:
        return self.xor_(a, b), self.and_(a, b)

    def full_adder(self, a: int, b: int, c: int) -> tuple[int, int]:
        s1 = self.xor_(a, b)
        s = self.xor_(s1, c)
        c1 = self.and_(a, b)
        c2 = self.and_(s1, c)
        return s, self.or_(c1, c2)

    def const0(self) -> int:
        """A constant-0 wire: XOR(x, x) of input 0."""
        return self.gate(gates.XOR, 0, 0)

    def finish(self, outs: list[int], spec: CGPSpec) -> Genome:
        assert len(outs) == spec.n_o
        assert len(self.nodes) <= spec.n_n, (
            f"netlist needs {len(self.nodes)} nodes > spec.n_n={spec.n_n}")
        nodes = list(self.nodes)
        # pad with inert BUF(0) nodes — they are inactive by construction
        while len(nodes) < spec.n_n:
            nodes.append((0, 0, gates.BUF))
        import jax.numpy as jnp
        return Genome(jnp.asarray(np.array(nodes, dtype=np.int32)),
                      jnp.asarray(np.array(outs, dtype=np.int32)))


def ripple_carry_adder(width: int, n_n: int | None = None) -> tuple[Genome, CGPSpec]:
    """width-bit + width-bit -> (width+1)-bit ripple-carry adder.

    Inputs: a[0..w-1] = indices 0..w-1 (LSB first), b = indices w..2w-1.
    """
    n_i, n_o = 2 * width, width + 1
    nb = NetBuilder(n_i, n_o)
    outs = []
    s, c = nb.half_adder(0, width)
    outs.append(s)
    for i in range(1, width):
        s, c = nb.full_adder(i, width + i, c)
        outs.append(s)
    outs.append(nb.buf(c))
    spec = CGPSpec(n_i=n_i, n_o=n_o, n_n=n_n or max(16, len(nb.nodes)))
    return nb.finish(outs, spec), spec


def array_multiplier(width: int, n_n: int | None = None) -> tuple[Genome, CGPSpec]:
    """width x width -> 2*width unsigned array multiplier (the paper's golden).

    Inputs: a = indices 0..w-1 (LSB first), b = indices w..2w-1.
    Row-by-row carry-save reduction with a final ripple row, the textbook
    array-multiplier structure.
    """
    w = width
    n_i, n_o = 2 * w, 2 * w
    nb = NetBuilder(n_i, n_o)

    # partial products pp[i][j] = a_j & b_i
    pp = [[nb.and_(j, w + i) for j in range(w)] for i in range(w)]

    outs = [pp[0][0]]
    # running row: bits of the current partial sum, LSB already emitted.
    row = pp[0][1:]          # w-1 bits: weights 1..w-1 relative to current row
    carry = None
    for i in range(1, w):
        new_row = []
        carry = None
        for j in range(w):
            # add pp[i][j] (weight i+j) to row bit (weight i+j) and carry
            acc = row[j] if j < len(row) else None
            p = pp[i][j]
            if acc is None and carry is None:
                s, carry = p, None
                new_row.append(s)
            elif acc is None:
                s, carry = nb.half_adder(p, carry)
                new_row.append(s)
            elif carry is None:
                s, carry = nb.half_adder(p, acc)
                new_row.append(s)
            else:
                s, carry = nb.full_adder(p, acc, carry)
                new_row.append(s)
        outs.append(new_row[0])
        row = new_row[1:]
        if carry is not None:
            row = row + [carry]
            carry = None
    # final row bits are the top output bits
    outs.extend(row)
    while len(outs) < n_o:
        outs.append(nb.const0())
    spec = CGPSpec(n_i=n_i, n_o=n_o, n_n=n_n or max(16, len(nb.nodes)))
    return nb.finish(outs, spec), spec


def golden_values(width: int, kind: str = "mul") -> np.ndarray:
    """int32 exact outputs over the exhaustive input cube (LSB-first operands).

    Tiled to at least 32 entries to match ``simulate.input_planes`` packing
    of sub-word cubes (see there for why replication is exact).
    """
    n = 1 << (2 * width)
    xs = np.arange(max(n, 32), dtype=np.int64) % n
    a = xs & ((1 << width) - 1)
    b = xs >> width
    if kind == "mul":
        return (a * b).astype(np.int32)
    if kind == "add":
        return (a + b).astype(np.int32)
    raise ValueError(kind)

"""Island-model elite migration between sweep pods (DESIGN.md §11).

The pod-sharded sweep (DESIGN.md §6) runs disjoint slices of the chunk plan
with zero runtime coordination — pods never benefit from each other's
discoveries.  This module adds the standard evolutionary island lever at the
CHUNK level: the pod-sliced chunk sequence is cut into fixed *epochs* of
``migrate_every`` chunks, and

  * after finishing the last chunk of its own epoch ``g``, a pod publishes
    its per-σ-group elite genomes as one fingerprint-stamped, atomically
    committed ``migrants_pod{i}_gen{g}.npz`` under the shared
    ``results_dir`` (``atomic_save_npz`` — presence == published, re-publish
    after a crash/resume rewrites identical bytes, so it is idempotent);
  * before running any chunk of epoch ``e >= 1``, a pod imports the epoch
    ``e-1`` migrant files of EVERY pod whose slice contains a complete
    epoch ``e-1`` (a deterministic function of the chunk plan — the import
    set never depends on timing), waiting for laggards up to
    ``migrate_timeout``;
  * imported elites with the chunk's σ are merged under a deterministic
    rule — sorted by ``(power_rel, phenotype digest)``, digest-deduplicated,
    capped at ``MIGRATE_TOP_K`` — and folded into the chunk's INITIAL
    population: each run adopts the migrant with the best Eq.(8)/(9) fitness
    under its own thresholds iff that fitness is STRICTLY better than the
    golden parent's (``fold_segment``, mirroring ``evolve._migrate``'s
    strictly-worse adoption rule).

Determinism: the import set is pinned by the plan, the merge key is
content-based, and adoption is per-run argmin with first-index tie-breaks —
so neither pod start order, file arrival order, nor concatenation order can
change results.  Migration IS result-changing, so ``sweep.grid_fingerprint``
gains a ``migrate`` key when (and only when) it is on; with
``migrate_every=0`` fingerprints, shards and stdout are byte-identical to
the migration-less engine.
"""
from __future__ import annotations

import functools
import os
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import atomic_save_npz
from repro.core.evolve import EvolveConfig, EvolveState
from repro.core.fitness import fitness as fitness_fn
from repro.core.genome import (CGPSpec, Genome, PHENOTYPE_DIGEST_SIZE,
                               phenotype_digests)

#: migrants kept per σ group per merge (publish caps per group too, so one
#: migrant file holds at most top_k × σ-groups rows).  Part of the grid
#: fingerprint's ``migrate`` key — changing it changes results.
MIGRATE_TOP_K = 4

_MIGRANT_RE = re.compile(r"^migrants_pod(\d+)_gen(\d+)\.npz$")


def migrant_name(pod: int, gen: int) -> str:
    return f"migrants_pod{pod}_gen{gen}.npz"


def select_elites(nodes: np.ndarray, outs: np.ndarray, power_rel: np.ndarray,
                  feas: np.ndarray, sigmas: np.ndarray, spec: CGPSpec,
                  top_k: int = MIGRATE_TOP_K) -> dict[str, np.ndarray]:
    """Per-σ-group elites of one epoch's committed rows, as migrant arrays.

    Only rows feasible under their OWN run's constraints qualify (an
    infeasible low-power genome is noise to every importer); per σ group the
    survivors are sorted by ``(power_rel, digest)``, digest-deduplicated and
    capped at ``top_k``.  Deterministic given the rows — publication order /
    row order cannot change the output bytes.
    """
    digs = phenotype_digests(nodes, outs, spec)
    picked: list[int] = []
    for sig in sorted(set(float(s) for s in sigmas)):
        cand = [i for i in range(len(sigmas))
                if float(sigmas[i]) == sig and feas[i]]
        cand.sort(key=lambda i: (float(power_rel[i]), digs[i]))
        seen: set[bytes] = set()
        for i in cand:
            if digs[i] in seen:
                continue
            seen.add(digs[i])
            picked.append(i)
            if len(seen) == top_k:
                break
    idx = np.asarray(picked, dtype=np.int64)
    dig_arr = np.frombuffer(b"".join(digs[i] for i in picked),
                            dtype=np.uint8).reshape(len(picked),
                                                    PHENOTYPE_DIGEST_SIZE) \
        if picked else np.zeros((0, PHENOTYPE_DIGEST_SIZE), np.uint8)
    return {
        "sigma": np.asarray(sigmas, np.float32)[idx],
        "nodes": np.asarray(nodes, np.int32)[idx],
        "outs": np.asarray(outs, np.int32)[idx],
        "power_rel": np.asarray(power_rel, np.float32)[idx],
        "digest": dig_arr,
    }


class MigrationManager:
    """One pod's migration endpoint: epoch bookkeeping, publish, import.

    Args:
      results_dir: the shared sweep directory migrant files live in.
      pod: this pod's index.
      pod_lens: per-pod slice lengths of the deterministic chunk plan
        (``len(s) for s in pod_partition(chunks, n_pods)``) — they define
        which pods publish which epochs, so the import set is a function of
        the plan alone.
      period: ``migrate_every`` — chunks per epoch.
      fingerprint: the grid fingerprint every migrant file is stamped with
        (imports refuse mismatches: stale files of another grid in a shared
        directory are a config error, not data).
      timeout: seconds to wait for a required peer file before raising.
    """

    def __init__(self, results_dir: str, pod: int, pod_lens: list[int],
                 period: int, fingerprint: str, *, timeout: float = 120.0,
                 top_k: int = MIGRATE_TOP_K, poll: float = 0.05):
        self.results_dir = results_dir
        self.pod = pod
        self.pod_lens = list(pod_lens)
        self.period = period
        self.fingerprint = fingerprint
        self.timeout = timeout
        self.top_k = top_k
        self.poll = poll
        self.stats = {"published": 0, "imported": 0, "adopted": 0,
                      "waited_s": 0.0}
        self._epochs: dict[int, dict[str, np.ndarray]] = {}

    # -- publish -----------------------------------------------------------

    def epoch_of(self, pos: int) -> int:
        """Epoch of a pod-slice position."""
        return pos // self.period

    def publishes_at(self, pos: int) -> int | None:
        """The epoch completed at slice position ``pos`` (None if ``pos`` is
        not an epoch boundary — partial trailing epochs are never
        published, and never required by any importer)."""
        return self.epoch_of(pos) if (pos + 1) % self.period == 0 else None

    def maybe_publish(self, epoch: int, elites: dict[str, np.ndarray]
                      ) -> str | None:
        """Commit this pod's epoch file unless already present (resume:
        re-deriving from committed shards yields identical bytes, so
        skipping is purely an I/O save)."""
        path = os.path.join(self.results_dir,
                            migrant_name(self.pod, epoch))
        if os.path.exists(path):
            return None
        out = dict(elites)
        out["fingerprint"] = np.array(self.fingerprint)
        out["epoch"] = np.array(epoch, np.int64)
        out["pod"] = np.array(self.pod, np.int64)
        atomic_save_npz(path, out)
        self.stats["published"] += 1
        return path

    # -- import ------------------------------------------------------------

    def publishers(self, epoch: int) -> list[int]:
        """Pods whose slice contains a COMPLETE epoch ``epoch`` — the exact
        file set every importer of epoch ``epoch`` waits for."""
        need = (epoch + 1) * self.period
        return [q for q, n in enumerate(self.pod_lens) if n >= need]

    def _load_epoch(self, epoch: int) -> dict[str, np.ndarray]:
        if epoch in self._epochs:
            return self._epochs[epoch]
        parts = []
        for q in self.publishers(epoch):
            path = os.path.join(self.results_dir, migrant_name(q, epoch))
            deadline = time.monotonic() + self.timeout
            while not os.path.exists(path):
                if time.monotonic() >= deadline:
                    raise RuntimeError(
                        f"pod {self.pod}: migrant file {path!r} (epoch "
                        f"{epoch}, pod {q}) still missing after "
                        f"{self.timeout:.0f}s — is that pod running? "
                        f"(relaunch it, raise migrate_timeout, or disable "
                        f"migration)")
                time.sleep(self.poll)
                self.stats["waited_s"] += self.poll
            with np.load(path) as z:
                fp = str(z["fingerprint"][()])
                if fp != self.fingerprint:
                    raise ValueError(
                        f"migrant file {path!r} stamped with a different "
                        f"grid fingerprint ({fp[:12]}… != "
                        f"{self.fingerprint[:12]}…) — stale file from "
                        f"another grid in this results_dir")
                parts.append({k: z[k] for k in
                              ("sigma", "nodes", "outs", "power_rel",
                               "digest")})
        merged = {k: np.concatenate([p[k] for p in parts])
                  for k in parts[0]} if parts else {
            "sigma": np.zeros((0,), np.float32)}
        self._epochs[epoch] = merged
        return merged

    def candidates(self, epoch: int, sigma: float
                   ) -> tuple[np.ndarray, np.ndarray] | None:
        """Merged ``(nodes, outs)`` import candidates of one σ group, in the
        deterministic ``(power_rel, digest)`` order, deduplicated and capped
        at ``top_k``; None when the epoch published nothing for this σ.

        The sort key is content-based, so the concatenation order of the
        pod files (and hence pod start order) cannot change the result.
        """
        mig = self._load_epoch(epoch)
        rows = np.flatnonzero(mig["sigma"] == np.float32(sigma))
        if rows.size == 0:
            return None
        order = sorted(
            rows.tolist(),
            key=lambda i: (float(mig["power_rel"][i]),
                           mig["digest"][i].tobytes()))
        seen: set[bytes] = set()
        keep: list[int] = []
        for i in order:
            d = mig["digest"][i].tobytes()
            if d in seen:
                continue
            seen.add(d)
            keep.append(i)
            if len(keep) == self.top_k:
                break
        self.stats["imported"] += len(keep)
        return mig["nodes"][keep], mig["outs"][keep]


@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def fold_segment(spec: CGPSpec, cfg: EvolveConfig, state: EvolveState,
                 mig_nodes: jax.Array, mig_outs: jax.Array,
                 mig_mv: jax.Array, mig_pw: jax.Array, thr_mat: jax.Array
                 ) -> tuple[EvolveState, jax.Array]:
    """Fold evaluated migrants into a chunk's initial state.

    ``mig_*`` carry a leading migrant axis (padded to a power-of-two bucket
    by repeating row 0 — duplicates sit AFTER the real rows, so the
    first-index ``argmin`` tie-break is unaffected).  Per run, the migrant
    with the lowest Eq.(8)/(9) fitness under that run's thresholds replaces
    the golden parent iff STRICTLY better (``evolve._migrate``'s
    strictly-worse adoption rule); ``best``/``best_fit`` track it the same
    way.  Returns the folded state and the number of adopting runs.
    """
    fits = jax.vmap(lambda t: jax.vmap(
        lambda p, m: fitness_fn(p, m, t))(mig_pw, mig_mv))(thr_mat)  # (C, Mp)
    j = jnp.argmin(fits, axis=1)                                     # (C,)
    fbest = jnp.take_along_axis(fits, j[:, None], axis=1)[:, 0]
    sel = Genome(mig_nodes[j], mig_outs[j])
    take = fbest < state.parent_fit

    def w(flag, a, b):
        return jnp.where(flag.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)

    parent = Genome(w(take, sel.nodes, state.parent.nodes),
                    w(take, sel.outs, state.parent.outs))
    improves = fbest < state.best_fit
    best = Genome(w(improves, sel.nodes, state.best.nodes),
                  w(improves, sel.outs, state.best.outs))
    folded = EvolveState(
        parent=parent,
        parent_fit=jnp.where(take, fbest, state.parent_fit),
        parent_metrics=w(take, mig_mv[j], state.parent_metrics),
        parent_power=jnp.where(take, mig_pw[j], state.parent_power),
        best=best,
        best_fit=jnp.where(improves, fbest, state.best_fit),
        key=state.key)
    return folded, take.sum()

"""Batched constraint-grid sweep engine (paper Sec. IV at scale).

The paper's experiment is a grid of ~27k (1+λ) runs over combined
error-constraint configurations × seeds.  ``search.run_sweep`` used to walk
that grid with a serial Python loop around ``evolve`` — one XLA program
dispatch per run, golden arrays rebuilt and the evolve program re-traced per
seed.  This module evaluates the whole grid as ONE jit'd program per chunk:

  * the threshold grid is stacked into a ``(chunk, N_METRICS)`` matrix and the
    per-run PRNG keys into ``(chunk, 2)``; ``make_batched_generation_step`` /
    ``init_state_batched`` from ``core.evolve`` carry that run axis — mutation
    and selection are vmapped per run, but each generation's whole
    (chunk × λ) offspring population is evaluated in one shot (for
    ``backend="pallas"`` a single fused kernel dispatch with the genome axis
    on the Pallas grid),
  * the golden circuit, input cube and golden power come from ONE
    ``problem_arrays`` call, are closed over, and are never re-traced — under
    vmap they stay unbatched so XLA shares them across every run,
  * generations are scanned on the OUTSIDE with the run axis inside the scan
    body (``scan_generations`` over a vmapped step), so candidate evaluation
    fuses across runs — on CPU this amortizes the per-op scheduling overhead
    that dominates small-width runs; on TPU it feeds the VPU full lanes.

Chunked execution bounds device memory: the grid is split into
``chunk_size``-run batches (peak live simulation state is roughly
``chunk_size × λ × n_wires × n_words × 4`` bytes) and chunks are padded to a
fixed width so every chunk with the same Gauss σ reuses one compiled program.
Runs with different ``gauss_sigma`` cannot share a trace (σ fixes the static
histogram bin edges), so chunk boundaries additionally break on σ changes.

Progress is resumable: after every ``checkpoint_every`` chunks the full sweep
state (evolved parent/best genomes, fitness, final metrics and optional
per-generation histories) is committed through ``repro.checkpoint.store``;
a restarted sweep with the same grid fingerprint continues mid-grid from the
last committed chunk.

Results stream to disk instead of accumulating in host RAM when
``SweepConfig.results_dir`` is set: every finished chunk is committed as one
append-only shard through ``core.results.SweepResultWriter`` and the shard
set is itself the resume state (see ``core.results`` for the schema).  The
``keep_history`` mode picks what stays in RAM — at paper scale (27k runs)
only ``"summary"``/``"none"`` keep the host footprint flat.

Multi-host execution shards the grid over the ``pod`` mesh axis
(DESIGN.md §6): with ``SweepConfig.n_pods > 1`` the deterministic chunk plan
is round-robin partitioned across pods (``results.pod_partition``) and THIS
process executes only pod ``pod_index``'s slice — each pod dispatches its own
(chunk × λ) fused program and commits its own shards into the shared
``results_dir``, whose one-time manifest is the only cross-pod coordination.
Resume is per pod (each pod skips the committed prefix of its OWN span
sequence), and because every chunk's bytes are a deterministic function of
the fingerprinted grid, a pod-sharded sweep produces bit-identical shards to
the single-host run of the same grid.  ``SweepConfig.model_axis``
additionally shards each dispatch's input cube over that mesh axis
(``shard_map`` around ``evolve_chunk``; evaluation partials psum through the
cube-shard kernel variant), fusing pods × chunk × λ × cube-shards into one
dispatch per generation per pod.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core import certify
from repro.core import metrics as M
from repro.core import migrate as migrate_mod
from repro.core import simulate
from repro.core.commit import ChunkCommitter
from repro.core.evalcache import PhenotypeLRU
from repro.core.results import (SweepResultReader, SweepResultWriter,
                                normalize_history_mode, pod_partition)
from repro.core.evolve import (EvolveConfig, eval_segment, init_state_batched,
                               make_batched_generation_step, mutate_segment,
                               scan_generations, select_segment)
from repro.core.fitness import ConstraintSpec, feasible
from repro.core.genome import CGPSpec, Genome, phenotype_digests
from repro.core.power import circuit_cost_from_probs


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Execution knobs of the batched sweep (grid semantics live in
    ``SearchConfig``/``ConstraintSpec``).

    ``keep_history`` picks where per-generation parent histories live
    (legacy bools are accepted: ``True`` -> ``"full"``, ``False`` ->
    ``"none"``):

      * ``"full"``    — histories kept in host RAM on the returned
        ``SweepResult`` (``hist_*`` arrays, ``(n_runs, gens, ...)``) and,
        when ``results_dir`` is set, spilled to shards too.  RAM grows with
        grid size — fine for small grids, not for the paper's 27k runs.
      * ``"summary"`` — histories are spilled to ``results_dir`` shards but
        NEVER held in RAM (``SweepResult.hist_*`` are None); read them back
        one chunk at a time via ``SweepResultReader.iter_history``.  Peak
        host memory is one chunk of history — independent of grid size.
        Without a ``results_dir`` the histories are dropped.
      * ``"none"``    — no histories anywhere (smallest shards/checkpoints).

    ``results_dir`` enables the streaming results layer (``core.results``):
    every finished chunk commits one append-only shard, and the shard set is
    the resume state — a restarted sweep with the same grid fingerprint
    continues after the last committed shard (``checkpoint_dir`` is then
    redundant for resume; shards commit every chunk, checkpoints every
    ``checkpoint_every`` chunks).

    ``checkpoint_dir`` is best given one directory per grid: resume matches
    checkpoints by grid fingerprint so foreign checkpoints are never loaded,
    but step numbers are run counts, and two grids sharing a directory can
    overwrite each other's equal-numbered steps (older ones are also pruned,
    keep=3, after each commit).

    ``n_pods``/``pod_index`` pod-shard the grid (DESIGN.md §6): the chunk
    plan is round-robin partitioned over ``n_pods`` and this process runs
    only pod ``pod_index``'s slice (every pod of a multi-host launch runs
    the same command with its own index; ``pod_index=None`` resolves it from
    the active mesh / process index via ``parallel.ctx.default_pod_index``).
    Multi-pod sweeps REQUIRE a shared ``results_dir`` (the shard set is the
    only resume state whose coverage tolerates per-pod prefixes) and refuse
    ``checkpoint_dir`` (checkpoints assume one global prefix).

    ``layout`` overrides the Pallas evaluation-grid order for every chunk
    dispatch of THIS sweep (``None`` defers to ``cfg.evolve.layout``):
    ``"genome_major"``, ``"cube_major"``, or ``"auto"`` (measured
    tuning-table resolution, DESIGN.md §7).  A pure execution knob — runs
    are bit-identical across layouts, the grid fingerprint ignores it, and
    a sweep checkpointed under one layout resumes under another.

    ``model_axis`` names a mesh axis of the ACTIVE ``parallel.ctx`` mesh to
    input-space-shard every dispatch over: ``evolve_chunk`` runs under
    ``shard_map`` with the cube's word axis split across it and evaluation
    partials psum'd (the cube-shard kernel variant), per-run state
    replicated.  Selection under MAE/WCE/ER/AVG/ACC0 constraints stays
    bit-identical to the unsharded dispatch (integer-exact partials); MRE
    sums are reassociated, so MRE-constrained runs are only allclose.

    ``dedup`` overrides the phenotype-dedup evaluation cache for every chunk
    of THIS sweep (``None`` defers to ``cfg.evolve.dedup``; DESIGN.md §8):
    offspring sharing an active subgraph are evaluated once per generation,
    and a cross-generation host LRU (``dedup_cache_size`` entries, keyed by
    phenotype digest × grid fingerprint × σ) skips the kernel for phenotypes
    it has already measured.  Execution-only like ``layout`` — results are
    bit-identical with the cache on or off, the grid fingerprint ignores it,
    and checkpoints/shards resume across the setting.  Measured hit/miss
    counters come back on ``SweepResult.dedup_stats``.  Incompatible with
    ``model_axis`` (the dedup loop is host-driven; a cube-sharded dispatch
    is one fused program).

    ``async_commit`` moves shard/checkpoint commits onto a bounded
    single-worker background thread (``core.commit.ChunkCommitter``,
    DESIGN.md §11): chunk N+1 dispatches while chunk N's npz write + fsync
    runs off-thread.  Span order, the atomic-rename commit contract and
    error surfacing are all preserved (worker exceptions re-raise at the
    next submit/drain; the queue drains on every exit, ``KeyboardInterrupt``
    included), and the committed BYTES are identical to the synchronous
    path's — execution-only like ``layout``/``dedup``, never fingerprinted.
    ``commit_depth`` bounds how many chunk commits may be pending before
    the sweep loop blocks (host-memory backpressure).

    ``migrate_every`` turns on chunk-level island migration between pods
    (``core.migrate``, DESIGN.md §11): every ``migrate_every`` chunks of its
    OWN slice a pod publishes its per-σ-group elites as an atomic
    fingerprint-stamped ``migrants_pod{i}_gen{g}.npz`` under ``results_dir``,
    and every chunk of epoch ``e >= 1`` folds the epoch ``e-1`` elites of
    ALL publishing pods into its initial population under a deterministic
    ``(power_rel, digest)`` merge rule.  RESULT-CHANGING — unlike
    ``async_commit`` it joins the grid fingerprint (only when on, together
    with ``n_pods``/``chunk_size``/``MIGRATE_TOP_K``, since the epoch
    structure depends on the plan), so ``migrate_every=0`` fingerprints and
    shards stay byte-identical to the migration-less engine.  Requires
    ``results_dir`` (the migrant files ride the shared directory) and
    refuses ``model_axis`` (the fold runs between host-driven jit
    segments).  Importing waits up to ``migrate_timeout`` seconds for a
    lagging pod's migrant file before raising.
    """
    chunk_size: int = 32          # runs per jit'd batch (device-memory bound)
    checkpoint_dir: str | None = None
    checkpoint_every: int = 1     # chunks between checkpoint commits
    keep_history: str | bool = "full"  # "none" | "summary" | "full"
    results_dir: str | None = None     # streaming shard spill (core.results)
    max_chunks: int | None = None  # stop after N chunks (tests/ops drains)
    n_pods: int = 1               # pod-shard the chunk plan (DESIGN.md §6)
    pod_index: int | None = None  # this process's pod (None: resolve via ctx)
    model_axis: str | None = None  # mesh axis to shard the input cube over
    layout: str | None = None     # Pallas grid-layout override (DESIGN.md §7)
    dedup: bool | None = None     # phenotype-dedup cache override (§8)
    dedup_cache_size: int = 1 << 16  # cross-generation LRU entry bound
    async_commit: bool = False    # background shard/checkpoint commits (§11)
    commit_depth: int = 2         # pending commits before submit blocks
    migrate_every: int = 0        # chunks per migration epoch; 0 = off (§11)
    migrate_timeout: float = 120.0  # seconds to wait for a peer's migrants

    def __post_init__(self):
        if self.dedup_cache_size < 1:
            raise ValueError(f"dedup_cache_size must be >= 1, got "
                             f"{self.dedup_cache_size}")
        if self.commit_depth < 1:
            raise ValueError(
                f"commit_depth must be >= 1, got {self.commit_depth}")
        if self.migrate_every < 0:
            raise ValueError(
                f"migrate_every must be >= 0, got {self.migrate_every}")
        if self.migrate_every > 0:
            if self.results_dir is None:
                raise ValueError(
                    "migrate_every needs a results_dir: migrant files ride "
                    "the shared results directory (DESIGN.md §11)")
            if self.model_axis is not None:
                raise ValueError(
                    "migrate_every is incompatible with model_axis: the "
                    "migrant fold runs between host-driven jit segments, a "
                    "cube-sharded dispatch is one fused program "
                    "(DESIGN.md §11)")
        if self.layout not in (None, "auto", "genome_major", "cube_major"):
            raise ValueError(
                f"layout must be None, 'auto', 'genome_major' or "
                f"'cube_major', got {self.layout!r}")
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {self.chunk_size}")
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {self.checkpoint_every}")
        if self.n_pods < 1:
            raise ValueError(f"n_pods must be >= 1, got {self.n_pods}")
        if self.pod_index is not None and not (
                0 <= self.pod_index < self.n_pods):
            raise ValueError(f"pod_index {self.pod_index} outside "
                             f"[0, {self.n_pods})")
        if self.n_pods > 1:
            if self.results_dir is None:
                raise ValueError(
                    "multi-pod sweeps need a shared results_dir: the shard "
                    "set is the only resume state that tolerates per-pod "
                    "prefixes (DESIGN.md §6)")
            if self.checkpoint_dir is not None:
                raise ValueError(
                    "checkpoint_dir assumes a single global progress prefix; "
                    "multi-pod sweeps resume through the results_dir shards")
        object.__setattr__(self, "keep_history",
                           normalize_history_mode(self.keep_history))


@dataclasses.dataclass
class SweepResult:
    """Stacked output of a (possibly partial) grid sweep.

    Run-major arrays are ordered like the grid: ``constraints`` outer,
    ``seeds`` inner.  Execution internally groups runs by ``gauss_sigma``, so
    on an interrupted sweep (``max_chunks``) the completed rows need not be a
    prefix — ``done_mask`` marks them; ``records`` holds exactly the
    completed runs, in grid order.

    ``hist_*`` arrays are populated only with ``keep_history="full"``; in
    ``"summary"`` mode the histories live in the ``results_dir`` shards
    (``reader().iter_history()``), in ``"none"`` mode nowhere.
    """
    records: list                      # list[CircuitRecord], len == completed
    thresholds: np.ndarray             # (n_runs, N_METRICS)
    metrics: np.ndarray                # (n_runs, N_METRICS) final measurement
    metrics_stderr: np.ndarray         # (n_runs, N_METRICS) per-metric SEs
                                       # (zeros for exhaustive grids, §9)
    power_rel: np.ndarray              # (n_runs,)
    feasible: np.ndarray               # (n_runs,) bool
    best_fit: np.ndarray               # (n_runs,)
    hist_power_rel: np.ndarray | None  # (n_runs, gens)
    hist_fit: np.ndarray | None        # (n_runs, gens)
    hist_metrics: np.ndarray | None    # (n_runs, gens, N_METRICS)
    done_mask: np.ndarray              # (n_runs,) bool — rows populated
    completed: int
    n_runs: int
    runs_per_sec: float                # throughput of this call (0 if resumed
                                       # with nothing left to do)
    results_dir: str | None = None     # shard spill location, if streaming
    dedup_stats: dict | None = None    # phenotype-cache counters (§8), when
                                       # the dedup path ran this call
    certified_mask: np.ndarray | None = None  # (n_runs,) bool — rows whose
                                       # error metrics are EXACT (§10): the
                                       # whole grid for exhaustive sweeps,
                                       # escalated elites for sampled ones
    certify_stats: dict | None = None  # escalation counters, when the
                                       # §10 escalation tier ran this call
    migrate_stats: dict | None = None  # island-migration counters (§11):
                                       # published/imported/adopted/waited_s,
                                       # when chunk-level migration ran

    def reader(self) -> SweepResultReader:
        """Open the shard set this sweep streamed to (requires a
        ``SweepConfig.results_dir``)."""
        if self.results_dir is None:
            raise ValueError("sweep ran without results_dir — no shards")
        return SweepResultReader(self.results_dir)

    def correlations(self, feasible_only: bool = True) -> np.ndarray:
        """|Pearson| cross-metric correlation over completed runs."""
        from repro.core.pareto import metric_correlations
        mask = self.done_mask & (self.feasible if feasible_only else True)
        return metric_correlations(self.metrics[mask])

    def fronts(self, metric_indices: Sequence[int] = (M.MAE, M.ER),
               feasible_only: bool = True) -> dict[int, np.ndarray]:
        """Power-vs-metric Pareto fronts (paper Figs. 7-14 axes)."""
        from repro.core.pareto import sweep_fronts
        mask = self.done_mask & (self.feasible if feasible_only else True)
        return sweep_fronts(self.power_rel[mask],
                            self.metrics[mask], metric_indices)


# --------------------------------------------------------------------------
# Batched core (one chunk = one XLA program)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("spec", "cfg", "axis_name"))
def evolve_chunk(spec: CGPSpec, cfg: EvolveConfig, golden: Genome,
                 thr_mat: jax.Array, in_planes: jax.Array,
                 golden_vals: jax.Array, golden_power: jax.Array,
                 keys: jax.Array, axis_name: str | None = None):
    """Evolve ``thr_mat.shape[0]`` runs in one program.

    The serial ``evolve`` semantics are preserved per run (same per-run PRNG
    stream, same selection): generation scan outside, run axis inside the
    scan body via ``evolve.make_batched_generation_step``, which evaluates
    the whole (chunk × λ) offspring population in one shot per generation —
    for ``backend="pallas"`` that is a single fused kernel dispatch with the
    genome axis on the Pallas grid.  Histories are returned run-major.

    ``axis_name`` input-space-shards that dispatch (DESIGN.md §6): call
    under ``shard_map`` with ``in_planes``/``golden_vals`` split on their
    word/value axis and everything else replicated — evaluation partials
    combine across the axis, so every shard holds the replicated global
    result (``_sharded_chunk_fn`` builds exactly that wrapper).
    """
    batched_step = make_batched_generation_step(spec, cfg,
                                                axis_name=axis_name)
    state0 = init_state_batched(spec, cfg, golden, thr_mat, in_planes,
                                golden_vals, keys, axis_name=axis_name)
    state, (hp, hm, hf) = scan_generations(batched_step, state0, thr_mat,
                                           in_planes, golden_vals,
                                           golden_power, cfg.generations)
    return state, hp.T, jnp.swapaxes(hm, 0, 1), hf.T


_init_state_batched_jit = jax.jit(
    init_state_batched, static_argnames=("spec", "cfg", "axis_name"))


@functools.partial(jax.jit, static_argnames=("spec", "cfg"))
def evolve_chunk_seeded(spec: CGPSpec, cfg: EvolveConfig,
                        state0: "jax.Array", thr_mat: jax.Array,
                        in_planes: jax.Array, golden_vals: jax.Array,
                        golden_power: jax.Array):
    """``evolve_chunk`` from an EXPLICIT initial state (same generation scan,
    same histories) — the migration path's entry point (DESIGN.md §11): the
    driver builds ``state0`` via ``_init_state_batched_jit`` and optionally
    folds imported migrant elites into it (``migrate.fold_segment``) before
    the scan.  A migrating sweep routes EVERY chunk through this function —
    epoch-0 chunks (nothing to import yet) included — so all chunks of a σ
    group share one trace."""
    batched_step = make_batched_generation_step(spec, cfg)
    state, (hp, hm, hf) = scan_generations(batched_step, state0, thr_mat,
                                           in_planes, golden_vals,
                                           golden_power, cfg.generations)
    return state, hp.T, jnp.swapaxes(hm, 0, 1), hf.T


def _evolve_chunk_dedup(spec: CGPSpec, cfg: EvolveConfig, golden: Genome,
                        thr_mat: jax.Array, in_planes: jax.Array,
                        golden_vals: jax.Array, golden_power: jax.Array,
                        keys: jax.Array, cache: PhenotypeLRU,
                        scope: tuple, state0=None):
    """``evolve_chunk`` with the phenotype-dedup cache (DESIGN.md §8).

    The generation loop runs on the host so the dedup decision can happen in
    Python between jit segments: per generation, (1) ``mutate_segment``
    draws the (C × λ) offspring with exactly the scanned path's PRNG
    stream, (2) the offspring are canonicalized+hashed on the host and
    reduced to unique *uncached* phenotypes, (3) ``eval_segment`` dispatches
    only those (padded to a power-of-two bucket so jit retraces stay
    logarithmic in the population size), (4) the cached/shared results are
    scattered back to every copy and ``select_segment`` finishes the step.
    Every evaluation result a copy receives is the phenotype-invariant
    (metric_vec, power) projection (see ``core.evalcache``), so the returned
    state and histories are bit-identical to ``evolve_chunk``'s.

    ``scope`` pins the cache entries' validity (grid fingerprint, σ); the
    LRU itself lives across chunks of one sweep call.  An explicit
    ``state0`` (the migration path's folded initial state, DESIGN.md §11)
    replaces the golden-parent init.
    """
    C, lam = thr_mat.shape[0], cfg.lam
    if state0 is None:
        state0 = _init_state_batched_jit(spec, cfg, golden, thr_mat,
                                         in_planes, golden_vals, keys)
    state = state0
    stats = cache.stats
    hp, hm, hf = [], [], []
    for _ in range(cfg.generations):
        key, offspring = mutate_segment(spec, cfg, state)
        nodes = np.asarray(offspring.nodes).reshape(C * lam, spec.n_n, 3)
        outs = np.asarray(offspring.outs).reshape(C * lam, spec.n_o)
        digests = phenotype_digests(nodes, outs, spec)
        stats.candidates += len(digests)

        first: dict[bytes, int] = {}
        for i, d in enumerate(digests):
            if d in first:
                stats.dup_hits += 1
            else:
                first[d] = i
        values: dict[bytes, tuple] = {}
        miss_digests: list[bytes] = []
        for d in first:
            val = cache.get((d,) + scope)
            if val is None:
                miss_digests.append(d)
            else:
                stats.lru_hits += 1
                values[d] = val
        if miss_digests:
            rows = [first[d] for d in miss_digests]
            n_miss = len(rows)
            stats.evaluated += n_miss
            pad = 1 << (n_miss - 1).bit_length()  # bucketed jit shapes
            sel = np.asarray(rows + rows[:1] * (pad - n_miss))
            mv, pw = eval_segment(spec, cfg, jnp.asarray(nodes[sel]),
                                  jnp.asarray(outs[sel]), in_planes,
                                  golden_vals)
            mv = np.asarray(mv)[:n_miss]
            pw = np.asarray(pw)[:n_miss]
            for j, d in enumerate(miss_digests):
                values[d] = (mv[j], pw[j])
                cache.put((d,) + scope, values[d])

        mets = np.stack([values[d][0] for d in digests])
        pows = np.asarray([values[d][1] for d in digests], np.float32)
        state, (p_rel, p_met, p_fit) = select_segment(
            spec, cfg, state, key, offspring,
            jnp.asarray(mets.reshape(C, lam, M.N_METRICS)),
            jnp.asarray(pows.reshape(C, lam)), thr_mat, golden_power)
        hp.append(np.asarray(p_rel))
        hm.append(np.asarray(p_met))
        hf.append(np.asarray(p_fit))

    gens_axis = 1  # run-major histories, like evolve_chunk's returns
    return (state, np.stack(hp, axis=gens_axis),
            np.stack(hm, axis=gens_axis), np.stack(hf, axis=gens_axis))


@functools.lru_cache(maxsize=None)
def _sharded_chunk_fn(mesh, model_axis: str, spec: CGPSpec,
                      cfg: EvolveConfig):
    """jit(shard_map(evolve_chunk)) with the input cube sharded over
    ``model_axis`` — the pods × chunk × λ × cube-shards fusion of DESIGN.md
    §6.  Cached per (mesh, axis, problem): the returned callable reuses one
    trace per σ group exactly like the unsharded ``evolve_chunk``.

    Per-run state/thresholds/keys are replicated (mutation and selection are
    identical on every shard because the combined evaluation partials are);
    outputs are therefore replicated too, which is what ``out_specs=P()``
    with ``check_rep=False`` asserts (psum through the Pallas wrapper is
    opaque to shard_map's replication checker).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def call(gold_nodes, gold_outs, thr_mat, in_planes, golden_vals,
             golden_power, keys):
        return evolve_chunk(spec, cfg, Genome(gold_nodes, gold_outs),
                            thr_mat, in_planes, golden_vals, golden_power,
                            keys, axis_name=model_axis)

    rep = P()
    fn = shard_map(call, mesh=mesh,
                   in_specs=(rep, rep, rep, P(None, model_axis),
                             P(model_axis), rep, rep),
                   out_specs=rep, check_rep=False)
    return jax.jit(fn)


@functools.partial(jax.jit,
                   static_argnames=("spec", "gauss_sigma", "sampled"))
def characterize_chunk(spec: CGPSpec, gauss_sigma: float, nodes: jax.Array,
                       outs: jax.Array, thr_mat: jax.Array,
                       in_planes: jax.Array, golden_vals: jax.Array,
                       golden_power: jax.Array, sampled: bool = False):
    """Vmapped final measurement (metrics + power/delay + error moments).

    ``sampled`` additionally turns the second-moment partials into per-metric
    standard errors (DESIGN.md §9); exhaustive chunks report zeros (a census
    has no sampling error) so the returned tuple shape is mode-invariant.
    """
    def one(n, o, thr):
        g = Genome(n, o)
        wires = simulate.simulate_planes(g, spec, in_planes)
        cvals = simulate.unpack_values(wires[g.outs])
        partials = M.error_partials(golden_vals, cvals, gauss_sigma,
                                    n_bits=spec.n_o)
        met = M.finalize_metrics(partials, spec.n_o, gauss_sigma)
        if sampled:
            sterr = M.metric_stderr(partials, spec.n_o)
        else:
            sterr = jnp.zeros((M.N_METRICS,), jnp.float32)
        probs = simulate.signal_probabilities(wires[spec.n_i:])
        cost = circuit_cost_from_probs(g, spec, probs)
        emean, estd = M.error_moments(golden_vals, cvals)
        return (met, sterr, cost.power / golden_power, feasible(met, thr),
                emean, estd)

    return jax.vmap(one)(nodes, outs, thr_mat)


# --------------------------------------------------------------------------
# Grid planning / checkpoint layout
# --------------------------------------------------------------------------

def sweep_grid(constraints: Sequence[ConstraintSpec],
               seeds: Sequence[int]) -> list[tuple[ConstraintSpec, int]]:
    """Run order of the grid: constraints outer, seeds inner (the historical
    ``run_sweep`` order — records stay comparable across engines)."""
    return [(con, int(seed)) for con in constraints for seed in seeds]


def plan_chunks(sigmas: np.ndarray, chunk_size: int) -> list[tuple[int, int]]:
    """[start, end) chunk spans: ≤ chunk_size runs, uniform gauss_sigma."""
    spans, start = [], 0
    n = len(sigmas)
    while start < n:
        end = min(start + chunk_size, n)
        brk = np.flatnonzero(sigmas[start:end] != sigmas[start])
        if brk.size:
            end = start + int(brk[0])
        spans.append((start, end))
        start = end
    return spans


def grid_fingerprint(cfg, grid, keep_history: str | bool,
                     migrate: dict | None = None) -> str:
    """Identity of (problem, grid, history mode) — guards checkpoint resume
    AND the results-shard manifest (``core.results``).  The history mode is
    part of the identity because it changes the buffer/shard schema.

    ``migrate`` carries the chunk-level island-migration knobs when (and
    only when) migration is on (DESIGN.md §11) — migration is
    result-changing and its epoch structure depends on the chunk plan and
    pod partition, so they join the identity; ``None`` (migration off)
    leaves every pre-§11 fingerprint unchanged."""
    ecfg = cfg.evolve
    # the legacy bool spellings hash as bools so checkpoints written before
    # the mode strings existed still resume ("summary" is new, no legacy)
    keep_history = {"full": True, "none": False}.get(
        normalize_history_mode(keep_history), "summary")
    ident = {
        "width": cfg.width, "kind": cfg.kind, "n_n": cfg.n_n,
        "generations": ecfg.generations, "lam": ecfg.lam,
        "mutation_rate": ecfg.mutation_rate, "backend": ecfg.backend,
        "migrate_every": ecfg.migrate_every,
        "keep_history": keep_history,
        "grid": [(con.describe(), con.gauss_sigma, seed)
                 for con, seed in grid],
        "thresholds": hashlib.sha256(
            np.stack([con.thresholds() for con, _ in grid]).tobytes()
        ).hexdigest(),
    }
    # eval_mode is RESULT-changing (unlike layout/dedup): sampled grids key
    # on the full sample-stream identity so a checkpoint/shard set can never
    # resume under different evaluation inputs.  Exhaustive grids omit the
    # keys entirely — their fingerprints (and hence pre-§9 checkpoints)
    # are unchanged.
    if ecfg.eval_mode != "exhaustive":
        from repro.core import sampling
        ident["eval_mode"] = ecfg.eval_mode
        ident["sample_stream"] = sampling.stream_fingerprint(
            cfg.width, ecfg.sample_size, ecfg.input_dist, ecfg.sample_seed)
        # the exact-verification escalation tier (DESIGN.md §10) rewrites
        # escalated rows' shard metrics with certified values, so it is
        # result-changing for sampled grids; keys appear only when on, so
        # pre-§10 sampled (and all exhaustive) fingerprints are unchanged
        if getattr(ecfg, "certify", False):
            ident["certify"] = {"budget": int(ecfg.certify_budget)}
    if migrate:
        ident["migrate"] = migrate
    return hashlib.sha256(json.dumps(ident, sort_keys=True,
                                     default=float).encode()).hexdigest()


def _alloc_buffers(spec: CGPSpec, n_runs: int, gens: int,
                   keep_history: str) -> dict[str, np.ndarray]:
    """Grid-order host buffers; ``hist_*`` only in "full" mode (the other
    modes keep host RAM independent of the history volume)."""
    bufs = {
        "parent_nodes": np.zeros((n_runs, spec.n_n, 3), np.int32),
        "parent_outs": np.zeros((n_runs, spec.n_o), np.int32),
        "best_nodes": np.zeros((n_runs, spec.n_n, 3), np.int32),
        "best_outs": np.zeros((n_runs, spec.n_o), np.int32),
        "best_fit": np.zeros((n_runs,), np.float32),
        "metrics": np.zeros((n_runs, M.N_METRICS), np.float32),
        "metrics_stderr": np.zeros((n_runs, M.N_METRICS), np.float32),
        "power_rel": np.zeros((n_runs,), np.float32),
        "feasible": np.zeros((n_runs,), np.uint8),
        "certified_mask": np.zeros((n_runs,), np.uint8),
        "error_mean": np.zeros((n_runs,), np.float32),
        "error_std": np.zeros((n_runs,), np.float32),
    }
    if keep_history == "full":
        bufs["hist_power_rel"] = np.zeros((n_runs, gens), np.float32)
        bufs["hist_fit"] = np.zeros((n_runs, gens), np.float32)
        bufs["hist_metrics"] = np.zeros((n_runs, gens, M.N_METRICS),
                                        np.float32)
    return bufs


def _try_resume(ckpt_dir: str, bufs: dict, fingerprint: str) -> int:
    """Load the newest committed state OF THIS GRID in place; returns runs
    done.  Steps are scanned newest-first by fingerprint so a stale
    checkpoint of a different grid sharing the directory cannot shadow this
    grid's progress."""
    for step in reversed(store.committed_steps(ckpt_dir)):
        if store.load_metadata(ckpt_dir, step).get("fingerprint") \
                != fingerprint:
            continue
        tree, meta = store.load_checkpoint(ckpt_dir, step, bufs)
        for k, v in tree.items():
            bufs[k][...] = np.asarray(v)
        return int(meta["done"])
    return 0


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def run_sweep_batched(cfg, constraints: Sequence[ConstraintSpec],
                      seeds: Sequence[int] = (0,),
                      sweep: SweepConfig | None = None) -> SweepResult:
    """Execute the constraint×seed grid with the batched engine.

    ``cfg`` is a ``search.SearchConfig``; per-run results match the serial
    ``run_search`` path (same PRNG streams, same evaluation semantics).
    With ``sweep.results_dir`` every finished chunk streams to an on-disk
    shard (``core.results``) and the shard set is the resume state;
    otherwise resume goes through ``sweep.checkpoint_dir`` as before.

    With ``sweep.n_pods > 1`` this call executes ONE pod's slice of the
    chunk plan (DESIGN.md §6) — run it once per pod (one process per host
    on a multi-host mesh, each with its own ``pod_index``) against the
    shared ``results_dir``; the returned ``SweepResult`` covers everything
    committed so far (this pod's work plus other pods' restored shards),
    with ``done_mask`` marking the covered grid rows.
    """
    from repro.core.search import CircuitRecord, problem_arrays

    sweep = sweep or SweepConfig()
    mode = sweep.keep_history  # normalized by SweepConfig.__post_init__
    grid = sweep_grid(constraints, seeds)
    n_runs = len(grid)
    gens = cfg.evolve.generations
    gold, spec, in_planes, gvals, gpower = problem_arrays(cfg)

    thr = np.stack([con.thresholds() for con, _ in grid])
    keys = np.stack([np.asarray(jax.random.PRNGKey(s)) for _, s in grid])
    sigmas = np.array([con.gauss_sigma for con, _ in grid])

    # Execution order groups runs by gauss_sigma (stable, so grid order is
    # kept within a group): sigma-interleaved grids would otherwise shatter
    # into tiny chunks that padding blows back up to chunk_size.  Results are
    # scattered back to grid order; coverage is tracked per execution-order
    # chunk span (deterministic from the fingerprinted grid, so resume stays
    # valid and — multi-pod — tolerates other pods' gaps).
    perm = np.argsort(sigmas, kind="stable")
    chunks = plan_chunks(sigmas[perm], sweep.chunk_size)

    pod = sweep.pod_index
    if pod is None:
        if sweep.n_pods > 1:
            from repro.parallel import ctx
            pod = ctx.default_pod_index(sweep.n_pods)
        else:
            pod = 0

    sampled = cfg.evolve.eval_mode == "sampled"
    # the dedup cache scope must pin WHICH inputs an entry was measured on:
    # the sample-stream fingerprint joins (grid fingerprint, σ) for sampled
    # grids (DESIGN.md §9); exhaustive scopes are unchanged.
    sample_scope: tuple = ()
    if sampled:
        from repro.core import sampling
        sample_scope = (sampling.stream_fingerprint(
            cfg.width, cfg.evolve.sample_size, cfg.evolve.input_dist,
            cfg.evolve.sample_seed),)

    # exact-verification escalation tier (DESIGN.md §10): sampled grids
    # only — an exhaustive census is already exact, so every exhaustive row
    # is marked certified without escalation and ``certify`` is a no-op.
    certify_on = sampled and bool(getattr(cfg.evolve, "certify", False))
    policy = (certify.CertifyPolicy(budget=cfg.evolve.certify_budget)
              if certify_on else None)
    # budget position of each span in the FULL deterministic plan (not this
    # pod's slice), so pods and resumed sweeps budget identically
    plan_pos = {span: i for i, span in enumerate(chunks)}
    n_escalated = 0

    dedup = sweep.dedup if sweep.dedup is not None else cfg.evolve.dedup
    if dedup and sweep.model_axis is not None:
        # diagnosed before the mesh check: the incompatibility holds
        # whether or not a mesh is active
        raise ValueError(
            "dedup is incompatible with model_axis: the dedup generation "
            "loop is host-driven, a cube-sharded dispatch is one fused "
            "program (DESIGN.md §8)")
    cache = PhenotypeLRU(sweep.dedup_cache_size) if dedup else None

    if sweep.model_axis is not None:
        from repro.parallel import ctx
        mesh = ctx.get_mesh()
        if mesh is None or sweep.model_axis not in mesh.axis_names:
            raise ValueError(
                f"model_axis {sweep.model_axis!r} needs an active "
                f"parallel.ctx mesh carrying that axis (have: "
                f"{None if mesh is None else mesh.axis_names})")

    bufs = _alloc_buffers(spec, n_runs, gens, mode)
    migrating = sweep.migrate_every > 0
    fingerprint = grid_fingerprint(
        cfg, grid, mode,
        migrate={"every": sweep.migrate_every, "n_pods": sweep.n_pods,
                 "chunk_size": sweep.chunk_size,
                 "top_k": migrate_mod.MIGRATE_TOP_K} if migrating else None)
    writer = None
    exec_done = np.zeros(n_runs, bool)  # execution-order positions covered
    if sweep.results_dir:
        writer = SweepResultWriter(
            sweep.results_dir, grid_fingerprint=fingerprint,
            grid_meta=[{"constraint": con.describe(), "seed": seed,
                        "gauss_sigma": con.gauss_sigma}
                       for con, seed in grid],
            n_runs=n_runs, gens=gens, n_n=spec.n_n, n_o=spec.n_o,
            keep_history=mode, chunk_size=sweep.chunk_size,
            chunk_spans=chunks, n_pods=sweep.n_pods,
            problem_meta={"width": cfg.width, "kind": cfg.kind,
                          "n_n": spec.n_n})
        # shards commit every chunk (checkpoints only every
        # checkpoint_every), so they are the freshest resume state
        for s, e in writer.restore(bufs):
            exec_done[s:e] = True
    elif sweep.checkpoint_dir:
        exec_done[:_try_resume(sweep.checkpoint_dir, bufs, fingerprint)] = \
            True

    # multi-pod always has a writer (SweepConfig enforces results_dir), so
    # the manifest-pinned plan is the single source of the pod partition
    my_chunks = chunks if sweep.n_pods == 1 else writer.pod_spans(pod)

    # chunk-level island migration (DESIGN.md §11): the manager's epoch
    # bookkeeping is a function of the deterministic plan alone, so every
    # pod derives the same publish/import schedule with no coordination
    migrator = None
    if migrating:
        pod_lens = [len(s) for s in pod_partition(chunks, sweep.n_pods)]
        migrator = migrate_mod.MigrationManager(
            sweep.results_dir, pod, pod_lens, sweep.migrate_every,
            fingerprint, timeout=sweep.migrate_timeout)
        mig_eval_cache: dict[tuple, tuple | None] = {}

        def _publish_epoch(epoch: int) -> None:
            # derived from the committed grid-order rows of the epoch's own
            # spans — identical whether they ran just now or were restored
            # from shards, so resume republishes identical bytes
            spans = my_chunks[epoch * sweep.migrate_every:
                              (epoch + 1) * sweep.migrate_every]
            rows = np.concatenate([perm[s:e] for s, e in spans])
            migrator.maybe_publish(epoch, migrate_mod.select_elites(
                bufs["parent_nodes"][rows], bufs["parent_outs"][rows],
                bufs["power_rel"][rows],
                bufs["feasible"][rows].astype(bool), sigmas[rows], spec))

        def _migrant_batch(epoch: int, sigma: float, ecfg):
            # one import + evaluation per (epoch, σ); migrants are padded to
            # a power-of-two bucket by repeating row 0 AFTER the real rows,
            # so fold_segment's first-index argmin is unaffected
            mkey = (epoch, float(sigma))
            if mkey not in mig_eval_cache:
                cand = migrator.candidates(epoch, sigma)
                if cand is None:
                    mig_eval_cache[mkey] = None
                else:
                    mn, mo = cand
                    m = len(mn)
                    pad_to = 1 << (m - 1).bit_length()
                    rows = np.r_[np.arange(m),
                                 np.zeros(pad_to - m, np.int64)]
                    mn = jnp.asarray(mn[rows])
                    mo = jnp.asarray(mo[rows])
                    mmv, mpw = eval_segment(spec, ecfg, mn, mo, in_planes,
                                            gvals)
                    mig_eval_cache[mkey] = (mn, mo, mmv, mpw)
            return mig_eval_cache[mkey]

    # background commit pipeline (DESIGN.md §11): shard/checkpoint commits
    # run on one bounded worker so the next chunk dispatches immediately;
    # identical bytes, identical order, errors surface at the next submit
    committer = (ChunkCommitter(sweep.commit_depth) if sweep.async_commit
                 and (writer is not None or sweep.checkpoint_dir) else None)

    def _commit_checkpoint(tree: dict, done: int) -> None:
        store.save_checkpoint(sweep.checkpoint_dir, done, tree,
                              {"done": done, "fingerprint": fingerprint})
        store.cleanup(sweep.checkpoint_dir, keep=3)

    t0 = time.perf_counter()
    ran = chunks_run = 0
    try:
        for pos, (start, end) in enumerate(my_chunks):
            if exec_done[start:end].all():
                # committed by a previous (interrupted) sweep; an epoch whose
                # chunks were all restored may still owe its migrant file
                # (crash between the last shard commit and the publish)
                if migrator is not None and \
                        migrator.publishes_at(pos) is not None:
                    _publish_epoch(migrator.publishes_at(pos))
                continue
            if sweep.max_chunks is not None and \
                    chunks_run >= sweep.max_chunks:
                break
            n = end - start
            pad = sweep.chunk_size - n
            sel = perm[np.r_[start:end, np.full(pad, end - 1)]]  # pad: last
            orig = sel[:n]  # grid-order rows this chunk fills
            sigma = float(sigmas[orig[0]])
            ecfg = dataclasses.replace(cfg.evolve, gauss_sigma=sigma, seed=0)
            if sweep.layout is not None:
                ecfg = dataclasses.replace(ecfg, layout=sweep.layout)

            state0 = None
            if migrator is not None:
                # migration folds into an EXPLICIT initial state; every
                # chunk of a migrating sweep takes the seeded path (epoch-0
                # chunks included) so all chunks of a σ group share a trace
                state0 = _init_state_batched_jit(
                    spec, ecfg, gold, jnp.asarray(thr[sel]), in_planes,
                    gvals, jnp.asarray(keys[sel]))
                ep = migrator.epoch_of(pos)
                if ep >= 1:
                    mb = _migrant_batch(ep - 1, sigma, ecfg)
                    if mb is not None:
                        mn, mo, mmv, mpw = mb
                        state0, n_adopt = migrate_mod.fold_segment(
                            spec, ecfg, state0, mn, mo, mmv, mpw,
                            jnp.asarray(thr[sel]))
                        migrator.stats["adopted"] += int(n_adopt)

            if sweep.model_axis is not None:
                evolve_call = _sharded_chunk_fn(ctx.get_mesh(),
                                                sweep.model_axis, spec, ecfg)
                state, hp, hm, hf = evolve_call(
                    gold.nodes, gold.outs, jnp.asarray(thr[sel]), in_planes,
                    gvals, gpower, jnp.asarray(keys[sel]))
            elif dedup:
                state, hp, hm, hf = _evolve_chunk_dedup(
                    spec, ecfg, gold, jnp.asarray(thr[sel]), in_planes,
                    gvals, gpower, jnp.asarray(keys[sel]), cache,
                    (fingerprint, sigma) + sample_scope, state0=state0)
            elif state0 is not None:
                state, hp, hm, hf = evolve_chunk_seeded(
                    spec, ecfg, state0, jnp.asarray(thr[sel]), in_planes,
                    gvals, gpower)
            else:
                state, hp, hm, hf = evolve_chunk(
                    spec, ecfg, gold, jnp.asarray(thr[sel]), in_planes,
                    gvals, gpower, jnp.asarray(keys[sel]))
            met, sterr, prel, feas, emean, estd = characterize_chunk(
                spec, sigma, state.parent.nodes, state.parent.outs,
                jnp.asarray(thr[sel]), in_planes, gvals, gpower,
                sampled=sampled)

            nodes_np = np.asarray(state.parent.nodes)[:n]
            outs_np = np.asarray(state.parent.outs)[:n]
            met_np = np.asarray(met)[:n].copy()
            sterr_np = np.asarray(sterr)[:n].copy()
            feas_np = np.asarray(feas)[:n].astype(np.uint8)
            cert = np.zeros(n, np.uint8)
            if not sampled:
                cert[:] = 1  # the census is its own certificate (§10)
            elif certify_on:
                # escalate the best sampled-feasible elites to the exact
                # tier: their shard rows become certified-exact measurements
                cap = policy.chunk_budget(plan_pos[(start, end)], len(chunks))
                for r in certify.select_escalations(
                        feas_np, np.asarray(prel)[:n], cert, cap):
                    cmet = certify.certified_metrics(
                        nodes_np[r], outs_np[r], spec, cfg.kind, cfg.width,
                        sigma, dispatch_rows=policy.dispatch_rows)
                    met_np[r] = cmet
                    sterr_np[r] = 0.0  # no sampling error left to report
                    feas_np[r] = np.uint8(
                        certify.feasible_np(cmet, thr[orig[r]]))
                    cert[r] = 1
                    n_escalated += 1

            chunk_rows = {
                "parent_nodes": nodes_np,
                "parent_outs": outs_np,
                "best_nodes": np.asarray(state.best.nodes)[:n],
                "best_outs": np.asarray(state.best.outs)[:n],
                "best_fit": np.asarray(state.best_fit)[:n],
                "metrics": met_np,
                "metrics_stderr": sterr_np,
                "power_rel": np.asarray(prel)[:n],
                "feasible": feas_np,
                "certified_mask": cert,
                "error_mean": np.asarray(emean)[:n],
                "error_std": np.asarray(estd)[:n],
            }
            for key, rows in chunk_rows.items():
                bufs[key][orig] = rows
            if mode == "full":
                bufs["hist_power_rel"][orig] = np.asarray(hp)[:n]
                bufs["hist_fit"][orig] = np.asarray(hf)[:n]
                bufs["hist_metrics"][orig] = np.asarray(hm)[:n]
            if writer is not None:
                chunk_rows["grid_rows"] = orig.astype(np.int32)
                chunk_rows["thresholds"] = thr[orig]
                if mode != "none":
                    # histories spill per chunk and (in "summary" mode)
                    # never touch a grid-sized host buffer
                    chunk_rows["hist_power_rel"] = np.asarray(hp)[:n]
                    chunk_rows["hist_fit"] = np.asarray(hf)[:n]
                    chunk_rows["hist_metrics"] = np.asarray(hm)[:n]
                if committer is not None:
                    # chunk_rows and its arrays are freshly built per chunk
                    # and never mutated after this point — safe to hand to
                    # the worker without copying
                    committer.submit(writer.write_chunk, (start, end),
                                     chunk_rows)
                else:
                    writer.write_chunk((start, end), chunk_rows)

            exec_done[start:end] = True
            ran += n
            chunks_run += 1
            if migrator is not None and \
                    migrator.publishes_at(pos) is not None:
                _publish_epoch(migrator.publishes_at(pos))
            if sweep.checkpoint_dir and (
                    chunks_run % sweep.checkpoint_every == 0
                    or exec_done.all()):
                # single-pod only (multi-pod refuses checkpoint_dir):
                # coverage is a plain prefix, whose length is the step
                done = int(np.argmin(exec_done)) if not exec_done.all() \
                    else n_runs
                if committer is not None:
                    # snapshot: the loop keeps mutating bufs while the
                    # worker serializes
                    committer.submit(_commit_checkpoint,
                                     {k: v.copy() for k, v in bufs.items()},
                                     done)
                else:
                    _commit_checkpoint(bufs, done)
    except BaseException:
        # drain handed-over commits even while unwinding (KeyboardInterrupt
        # included) so they are durably committed or dropped-after-poison,
        # but never mask the in-flight exception with a worker's
        if committer is not None:
            committer.close(raise_errors=False)
        raise
    if committer is not None:
        committer.close()
    dt = time.perf_counter() - t0

    done_mask = np.zeros(n_runs, bool)
    done_mask[perm[exec_done]] = True
    records = []
    for i in np.flatnonzero(done_mask):
        con, seed = grid[i]
        records.append(CircuitRecord(
            genome_nodes=bufs["parent_nodes"][i],
            genome_outs=bufs["parent_outs"][i],
            metrics=bufs["metrics"][i],
            power_rel=float(bufs["power_rel"][i]),
            constraint=con.describe(),
            seed=seed,
            feasible=bool(bufs["feasible"][i]),
            error_mean=float(bufs["error_mean"][i]),
            error_std=float(bufs["error_std"][i]),
            metrics_stderr=bufs["metrics_stderr"][i],
            certified=bool(bufs["certified_mask"][i]),
        ))

    return SweepResult(
        records=records,
        thresholds=thr,
        metrics=bufs["metrics"],
        metrics_stderr=bufs["metrics_stderr"],
        power_rel=bufs["power_rel"],
        feasible=bufs["feasible"].astype(bool),
        best_fit=bufs["best_fit"],
        hist_power_rel=bufs.get("hist_power_rel"),
        hist_fit=bufs.get("hist_fit"),
        hist_metrics=bufs.get("hist_metrics"),
        done_mask=done_mask,
        completed=int(exec_done.sum()),
        n_runs=n_runs,
        runs_per_sec=(ran / dt) if ran else 0.0,
        results_dir=sweep.results_dir,
        dedup_stats=cache.stats.as_dict() if cache is not None else None,
        certified_mask=bufs["certified_mask"].astype(bool),
        certify_stats=({
            "escalated": n_escalated,
            "certified_rows": int(bufs["certified_mask"].sum()),
            "budget": int(cfg.evolve.certify_budget),
        } if certify_on else None),
        migrate_stats=dict(migrator.stats) if migrator is not None else None,
    )

"""Pareto-front utilities for the trade-off analyses (paper Figs. 7-14).

Besides the front/hypervolume primitives, this module holds the analysis end
of the sweep results path: cross-metric correlation matrices (Fig. 6) and
per-metric power-vs-error fronts (Figs. 7-14) over stacked ``(n_runs,
N_METRICS)`` summary columns.  Both the in-RAM ``sweep.SweepResult`` and the
on-disk ``results.SweepResultReader`` feed these functions the same arrays
(the reader scatters only the few-floats-per-run summary columns back to
grid order, never the per-generation histories), so the two paths are
bit-identical; ``benchmarks/paper_figures.py`` consumes them through the
reader of one shared sweep grid.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (all objectives minimized).

    Args:
      points: (N, K) array; NaN/inf rows are never selected.
    """
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    ok = np.isfinite(pts).all(axis=1)
    mask = np.zeros(n, dtype=bool)
    order = np.argsort(pts[:, 0], kind="stable")
    for i in order:
        if not ok[i]:
            continue
        dominated = False
        for j in np.flatnonzero(mask):
            if (pts[j] <= pts[i]).all() and (pts[j] < pts[i]).any():
                dominated = True
                break
        if not dominated:
            # remove points the new one dominates
            for j in np.flatnonzero(mask):
                if (pts[i] <= pts[j]).all() and (pts[i] < pts[j]).any():
                    mask[j] = False
            mask[i] = True
    return mask


def pareto_points(points: np.ndarray) -> np.ndarray:
    """Sorted (by first column) non-dominated subset."""
    m = pareto_front(points)
    sel = np.asarray(points)[m]
    return sel[np.argsort(sel[:, 0])]


def metric_correlations(metrics: np.ndarray) -> np.ndarray:
    """|Pearson| correlation across metric columns (paper Fig. 6).

    Args:
      metrics: (N, K) stacked per-run metric vectors, one column per metric
        in ``metrics.METRIC_NAMES`` order — ``SweepResult.metrics[mask]`` or
        the ``"metrics"`` column of ``results.SweepResultReader.summary()``
        (``SweepResultReader.correlations`` does the masking for you).
    Returns:
      (K, K) symmetric float64 matrix with unit diagonal.  Zero-variance
      columns and N < 3 give zero off-diagonals instead of NaNs (a constant
      metric is uninformative, not perfectly correlated).
    """
    X = np.asarray(metrics, dtype=np.float64)
    k = X.shape[1] if X.ndim == 2 else 0
    if X.ndim != 2 or X.shape[0] < 3:
        return np.eye(k)
    with np.errstate(invalid="ignore", divide="ignore"):
        C = np.abs(np.corrcoef(X.T))
    C = np.nan_to_num(C, nan=0.0)
    np.fill_diagonal(C, 1.0)
    return C


def sweep_fronts(power: np.ndarray, metrics: np.ndarray,
                 metric_indices: Sequence[int]) -> dict[int, np.ndarray]:
    """Power-vs-metric Pareto fronts of a sweep (paper Figs. 7-14 axes).

    Args:
      power:   (N,) relative power per run (``power(C)/power(G)``).
      metrics: (N, K) final metric vectors per run, columns in
        ``metrics.METRIC_NAMES`` order.
      metric_indices: which metric columns to build fronts for (e.g.
        ``(metrics.MAE, metrics.ER)``).
    Returns:
      {metric index: (M, 2) front of (power_rel, metric value) points,
      sorted by power} — both objectives minimized; rows with NaN/inf never
      enter a front.
    """
    power = np.asarray(power, dtype=np.float64)
    metrics = np.asarray(metrics, dtype=np.float64)
    return {int(i): pareto_points(np.stack([power, metrics[:, i]], axis=1))
            for i in metric_indices}


def hypervolume_2d(points: np.ndarray, ref: tuple[float, float]) -> float:
    """2-D hypervolume (both objectives minimized) w.r.t. reference point."""
    front = pareto_points(points)
    front = front[(front[:, 0] <= ref[0]) & (front[:, 1] <= ref[1])]
    if front.size == 0:
        return 0.0
    hv, prev_y = 0.0, ref[1]
    for x, y in front:
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return float(hv)

"""Pareto-front utilities for the trade-off analyses (paper Figs. 7-14)."""
from __future__ import annotations

import numpy as np


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (all objectives minimized).

    Args:
      points: (N, K) array; NaN/inf rows are never selected.
    """
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    ok = np.isfinite(pts).all(axis=1)
    mask = np.zeros(n, dtype=bool)
    order = np.argsort(pts[:, 0], kind="stable")
    for i in order:
        if not ok[i]:
            continue
        dominated = False
        for j in np.flatnonzero(mask):
            if (pts[j] <= pts[i]).all() and (pts[j] < pts[i]).any():
                dominated = True
                break
        if not dominated:
            # remove points the new one dominates
            for j in np.flatnonzero(mask):
                if (pts[i] <= pts[j]).all() and (pts[i] < pts[j]).any():
                    mask[j] = False
            mask[i] = True
    return mask


def pareto_points(points: np.ndarray) -> np.ndarray:
    """Sorted (by first column) non-dominated subset."""
    m = pareto_front(points)
    sel = np.asarray(points)[m]
    return sel[np.argsort(sel[:, 0])]


def hypervolume_2d(points: np.ndarray, ref: tuple[float, float]) -> float:
    """2-D hypervolume (both objectives minimized) w.r.t. reference point."""
    front = pareto_points(points)
    front = front[(front[:, 0] <= ref[0]) & (front[:, 1] <= ref[1])]
    if front.size == 0:
        return 0.0
    hv, prev_y = 0.0, ref[1]
    for x, y in front:
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return float(hv)

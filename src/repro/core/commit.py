"""Background commit executor for the batched sweep (DESIGN.md §11).

Every shard/checkpoint commit of ``core.sweep`` is host-side I/O on fully
materialized numpy arrays — with the fsync'd atomic renames of
``checkpoint.store`` it sits squarely on the sweep's critical path.  The
``ChunkCommitter`` moves those commits onto ONE background worker thread so
chunk N+1's device dispatch overlaps chunk N's npz write + fsync, without
giving up any of the synchronous path's guarantees:

  * **Span order is preserved.**  A single worker drains a FIFO queue, so
    commits land on disk in exactly the submission order — the per-pod
    committed-prefix resume rule (``results.pod_prefix_spans``) keeps
    working because a later chunk can never become visible before an
    earlier one of the same pod.
  * **Bounded queue.**  ``submit`` blocks once ``max_pending`` commits are
    in flight (queue + the one executing), so host memory holds at most a
    few chunks of rows no matter how far the device runs ahead.
  * **Exceptions are not lost.**  The first worker exception is re-raised
    on the producer thread at the next ``submit``/``drain``/``close`` —
    exactly where the synchronous path would have raised — and poisons the
    queue: once a commit failed, later queued commits are dropped (never
    executed), so a failed span can never be followed on disk by a
    committed successor (which the prefix rule would silently orphan).
  * **Drain on every exit.**  ``close`` (or the context manager, on normal
    exit AND on ``KeyboardInterrupt``/any exception) waits for the queued
    commits to finish before returning, so work that was handed over is
    either durably committed or surfaced as an error — never silently
    dropped mid-queue.

The committer is a pure execution detail: ``SweepConfig.async_commit`` is
never fingerprinted and the bytes it commits are identical to the
synchronous path's (same arrays, same writer).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable

__all__ = ["ChunkCommitter"]

_STOP = object()


class ChunkCommitter:
    """Bounded single-worker executor for ordered commit callables.

    Args:
      max_pending: commits allowed in the queue before ``submit`` blocks
        (backpressure).  The worker may hold one more in execution.
    """

    def __init__(self, max_pending: int = 2):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._error: BaseException | None = None
        self._closed = False
        self.committed = 0   # commits that ran to completion
        self.dropped = 0     # commits skipped after a poisoning failure
        self._thread = threading.Thread(target=self._worker,
                                        name="sweep-committer", daemon=True)
        self._thread.start()

    # -- worker ------------------------------------------------------------

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is _STOP:
                self._q.task_done()
                return
            fn, args, kwargs = item
            if self._error is None:
                try:
                    fn(*args, **kwargs)
                    self.committed += 1
                except BaseException as e:  # noqa: BLE001 — re-raised later
                    self._error = e
            else:
                # poisoned: a failed span must not be followed by a
                # committed successor
                self.dropped += 1
            self._q.task_done()

    # -- producer side -----------------------------------------------------

    def _raise_pending(self) -> None:
        if self._error is not None:
            raise self._error

    def submit(self, fn: Callable[..., Any], *args, **kwargs) -> None:
        """Enqueue one commit; blocks while ``max_pending`` are in flight.

        Re-raises the first pending worker exception BEFORE enqueueing, so
        the producer stops handing work to a failed pipeline at the same
        boundary the synchronous path would have stopped at.
        """
        if self._closed:
            raise RuntimeError("submit on a closed ChunkCommitter")
        self._raise_pending()
        self._q.put((fn, args, kwargs))

    def drain(self, raise_errors: bool = True) -> None:
        """Block until every queued commit has run (or been dropped); then
        re-raise the first worker exception unless ``raise_errors=False``
        (used while already unwinding another exception, to avoid masking
        it)."""
        self._q.join()
        if raise_errors:
            self._raise_pending()

    def close(self, raise_errors: bool = True) -> None:
        """Drain, stop the worker and join it.  Idempotent."""
        if not self._closed:
            self._closed = True
            self._q.join()
            self._q.put(_STOP)
            self._thread.join()
        if raise_errors:
            self._raise_pending()

    # -- context manager: drain on normal exit and on KeyboardInterrupt ----

    def __enter__(self) -> "ChunkCommitter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # on an in-flight exception (KeyboardInterrupt included) still drain
        # — handed-over commits finish — but don't let a worker error mask
        # the original exception
        self.close(raise_errors=exc_type is None)

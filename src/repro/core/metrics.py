"""Error metrics — paper Sec. II, Eq. (1)-(7).

All metrics are computed from integer output values over (a slice of) the
exhaustive input cube and are returned as *partial sums* so that input-space
sharding can combine shards with psum/pmax before normalization
(``finalize_metrics``).  Relativization follows the paper: magnitudes are
divided by the output range 2^m and reported in percent.

Metric vector layout (used by fitness thresholds; see ``fitness.py``):
    0 MAE_rel(%)  1 WCE_rel(%)  2 ER(%)  3 MRE(%)  4 |AVG|_rel(%)
    5 ACC0 (1 = holds)          6 GAUSS (1 = holds)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

MAE, WCE, ER, MRE, AVG, ACC0, GAUSS = range(7)
METRIC_NAMES = ("mae", "wce", "er", "mre", "avg", "acc0", "gauss")
N_METRICS = 7


class MetricPartials(NamedTuple):
    """Shard-combinable raw sums.  Combine: add all but wce_max (max).

    x64 is disabled (the LM substrate must stay 32-bit), so magnitude sums
    use an EXACT accumulation (``_exact_sum``) with two statically-chosen
    regimes.  Historic byte split: |e| = 256*hi + lo with hi/lo ≤ 2^8-1;
    partial sums of ≤2^16 byte-sized terms stay < 2^24 and are exact in
    float32; the recombination ``256*hi_sum + lo_sum`` is done in float32
    whose error is ≤ 1 ulp of the total (relative ~6e-8).  Outside that
    regime (operands wider than 16 bits or slices longer than 2^16, where
    the byte split would silently overflow 2^24) the sum switches to per-bit
    popcounts — int32-exact counts recombined in float32 with error
    ≤ n_bits ulp (relative ~n_bits·2^-24) — documented precision of the
    metric pipeline (tests assert rtol 1e-5 vs the int64 NumPy oracle up to
    width-12 value ranges).
    """
    abs_sum: jax.Array    # Σ |g - c|  (float32 via exact split sums)
    wce_max: jax.Array    # max |g - c|
    err_count: jax.Array  # #{x : g != c}
    rel_sum: jax.Array    # Σ |g-c| / max(g, 1)
    sgn_sum: jax.Array    # Σ (g - c)  (signed, Eq. 6)
    acc0_bad: jax.Array   # #{x : g = 0 ∧ c != 0}
    hist: jax.Array       # (n_bins,) signed-error histogram (zeros excluded)
    count: jax.Array      # #inputs in this slice
    sq_sum: jax.Array     # Σ (g - c)^2  (float32; variance estimator only)
    rel_sq: jax.Array     # Σ (|g-c| / max(g, 1))^2  (float32)


def gauss_bin_edges(sigma: float, n_side: int = 4) -> np.ndarray:
    """σ-wide bin edges covering ±n_side·σ, plus two open tail bins."""
    edges = np.arange(-n_side, n_side + 1, dtype=np.float64) * sigma
    return edges  # len 2*n_side+1 -> 2*n_side interior bins (+2 tails)


def gauss_bin_mass(sigma: float, n_side: int = 4) -> np.ndarray:
    """Expected probability mass per bin under N(0, σ) (tails included)."""
    from math import erf, sqrt
    edges = gauss_bin_edges(sigma, n_side)
    cdf = np.array([0.5 * (1 + erf(e / (sigma * sqrt(2)))) for e in edges])
    interior = np.diff(cdf)
    return np.concatenate([[cdf[0]], interior, [1.0 - cdf[-1]]])


def error_partials(golden: jax.Array, cand: jax.Array,
                   gauss_sigma: float, n_gauss_side: int = 4,
                   n_bits: int = 16) -> MetricPartials:
    """Raw per-slice sums from integer output values.

    Args:
      golden, cand: (S,) int32 exact / approximate outputs on this cube slice.
      gauss_sigma:  σ for the Gauss_σ histogram (static).
      n_bits:       static bound |g - c| < 2^n_bits (= the circuit's n_o);
                    picks the exact-sum regime (see ``_exact_sum``).
    """
    g = golden.astype(jnp.int32)
    c = cand.astype(jnp.int32)
    diff = g - c               # |diff| < 2^n_o ≤ 2^31, exact in int32
    ad = jnp.abs(diff)
    nz = diff != 0

    edges = jnp.asarray(gauss_bin_edges(gauss_sigma, n_gauss_side))
    n_bins = edges.shape[0] + 1
    bin_idx = jnp.searchsorted(edges, diff.astype(jnp.float32), side="right")
    hist = jnp.zeros((n_bins,), jnp.int32).at[bin_idx].add(
        nz.astype(jnp.int32))

    return MetricPartials(
        abs_sum=_exact_sum(ad, n_bits),
        # initial=0 is the identity (|diff| >= 0) AND makes the reduction
        # total on zero-size slices (empty sampled-shard partitions)
        wce_max=jnp.max(ad, initial=0),
        err_count=nz.sum(),
        rel_sum=(ad.astype(jnp.float32) /
                 jnp.maximum(g, 1).astype(jnp.float32)).sum(),
        sgn_sum=_exact_sum(jnp.maximum(diff, 0), n_bits) -
                _exact_sum(jnp.maximum(-diff, 0), n_bits),
        acc0_bad=((g == 0) & (c != 0)).sum(),
        hist=hist,
        count=jnp.asarray(diff.shape[0], jnp.int32),
        sq_sum=(ad.astype(jnp.float32) ** 2).sum(),
        rel_sq=((ad.astype(jnp.float32) /
                 jnp.maximum(g, 1).astype(jnp.float32)) ** 2).sum(),
    )


def _exact_sum(v: jax.Array, n_bits: int = 16) -> jax.Array:
    """Integer-exact Σv for 0 ≤ v < 2^n_bits (see MetricPartials doc).

    The regime is chosen STATICALLY from (n_bits, slice length), never from
    values, so it is jit-stable:

      * byte split (historic path) whenever both block sums provably stay
        < 2^24 — exact, and bit-identical with the Pallas kernel's in-kernel
        split accumulation for the ≤8-bit-operand cubes the kernel serves;
      * per-bit popcount otherwise: cnt_b = #{v with bit b set} is exact in
        int32 for any slice length (and float32-exact up to 2^24 terms);
        recombining Σ 2^b·cnt_b in float32, ascending, bounds the error at
        n_bits ulp of the total — vs the UNBOUNDED silent error the
        overflowed byte split used to produce for >8-bit operands
        (e.g. a 12×12 multiplier's n_o = 24).
    """
    n = int(np.prod(v.shape)) if v.shape else 1
    hi_max = max((1 << max(n_bits - 8, 0)) - 1, 0)
    if n * 255 < (1 << 24) and n * hi_max < (1 << 24):
        hi = (v >> 8).astype(jnp.float32)
        lo = (v & 0xFF).astype(jnp.float32)
        return 256.0 * hi.sum() + lo.sum()
    total = jnp.float32(0.0)
    for b in range(n_bits):  # ascending: small terms accumulate first
        cnt = ((v >> b) & 1).sum()
        total = total + float(1 << b) * cnt.astype(jnp.float32)
    return total


def combine_partials(p: MetricPartials, axis_name: str) -> MetricPartials:
    """psum/pmax partials across an input-space-sharding mesh axis."""
    ps = lambda x: jax.lax.psum(x, axis_name)
    return MetricPartials(
        abs_sum=ps(p.abs_sum), wce_max=jax.lax.pmax(p.wce_max, axis_name),
        err_count=ps(p.err_count), rel_sum=ps(p.rel_sum),
        sgn_sum=ps(p.sgn_sum), acc0_bad=ps(p.acc0_bad),
        hist=ps(p.hist), count=ps(p.count),
        sq_sum=ps(p.sq_sum), rel_sq=ps(p.rel_sq))


def finalize_metrics(p: MetricPartials, n_o: int, gauss_sigma: float,
                     n_gauss_side: int = 4,
                     gauss_slack: float = 1.0) -> jax.Array:
    """(N_METRICS,) float32 metric vector per the layout above.

    MAE/WCE/|AVG| are relativized to 2^n_o and expressed in PERCENT, as in the
    paper's figures; ER and MRE are percentages by definition.

    An empty shard (count == 0, possible with ragged sampled partitions)
    must finalize to all-zero sums / n=1, never 0/0 = NaN: NaN compares
    false against every threshold and silently poisons fitness selection.
    """
    out_range = float(1 << n_o)
    n = jnp.maximum(p.count.astype(jnp.float32), 1.0)
    mae = p.abs_sum.astype(jnp.float32) / n
    wce = p.wce_max.astype(jnp.float32)
    er = p.err_count.astype(jnp.float32) / n
    mre = p.rel_sum / n
    avg = p.sgn_sum.astype(jnp.float32) / n
    acc0 = (p.acc0_bad == 0).astype(jnp.float32)

    mass = jnp.asarray(gauss_bin_mass(gauss_sigma, n_gauss_side),
                       dtype=jnp.float32)
    allowed = mass * n * gauss_slack
    gauss_ok = jnp.all(p.hist.astype(jnp.float32) <= allowed)

    return jnp.stack([
        100.0 * mae / out_range,
        100.0 * wce / out_range,
        100.0 * er,
        100.0 * mre,
        100.0 * jnp.abs(avg) / out_range,
        acc0,
        gauss_ok.astype(jnp.float32),
    ])


def metric_stderr(p: MetricPartials, n_o: int) -> jax.Array:
    """(N_METRICS,) standard errors matching ``finalize_metrics`` units.

    CLT estimates from the sample second moments carried in the partials
    (shard-combinable: ``sq_sum``/``rel_sq`` psum like every other sum):

      * MAE / |AVG|:  sqrt(Var[|d|] / n), sqrt(Var[d] / n) — both from
        Σd² (|d|² = d²), scaled by 100/2^n_o like the point estimates;
      * ER:           Bernoulli sqrt(p̂(1-p̂)/n), in percent;
      * MRE:          sqrt(Var[rel] / n), in percent;
      * WCE / ACC0 / GAUSS: 0 — extreme-value / indicator metrics have no
        CLT interval; the sampled mode reports them as observed-on-sample
        (lower bounds), see DESIGN.md §9.

    Under exhaustive evaluation the "sample" is the full census, so the
    sampling error is zero by construction; callers report zeros there and
    only compute this for ``eval_mode="sampled"``.
    """
    out_range = float(1 << n_o)
    n = jnp.maximum(p.count.astype(jnp.float32), 1.0)
    mean_abs = p.abs_sum.astype(jnp.float32) / n
    mean_sgn = p.sgn_sum.astype(jnp.float32) / n
    mean_sq = p.sq_sum / n
    var_abs = jnp.maximum(mean_sq - mean_abs ** 2, 0.0)
    var_sgn = jnp.maximum(mean_sq - mean_sgn ** 2, 0.0)
    er_hat = p.err_count.astype(jnp.float32) / n
    var_er = jnp.maximum(er_hat * (1.0 - er_hat), 0.0)
    mre_hat = p.rel_sum / n
    var_rel = jnp.maximum(p.rel_sq / n - mre_hat ** 2, 0.0)
    rt_n = jnp.sqrt(n)
    zero = jnp.float32(0.0)
    return jnp.stack([
        100.0 * jnp.sqrt(var_abs) / rt_n / out_range,
        zero,
        100.0 * jnp.sqrt(var_er) / rt_n,
        100.0 * jnp.sqrt(var_rel) / rt_n,
        100.0 * jnp.sqrt(var_sgn) / rt_n / out_range,
        zero,
        zero,
    ])


def metrics_from_values(golden: jax.Array, cand: jax.Array, n_o: int,
                        gauss_sigma: float = 256.0) -> jax.Array:
    """Single-shard convenience: values -> finalized metric vector."""
    p = error_partials(golden, cand, gauss_sigma, n_bits=n_o)
    return finalize_metrics(p, n_o, gauss_sigma)


def error_moments(golden: jax.Array, cand: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(mean, std) of the signed error — exact, for Fig. 13-style analysis.

    int32 is exact here (|g - c| < 2^n_o ≤ 2^31); x64 is disabled repo-wide,
    so an int64 cast would silently truncate to int32 with a warning anyway.
    """
    diff = (golden.astype(jnp.int32) - cand.astype(jnp.int32)).astype(jnp.float32)
    return diff.mean(), diff.std()


# ------------------------- NumPy oracle (tests) -------------------------

def metrics_np(golden: np.ndarray, cand: np.ndarray, n_o: int,
               gauss_sigma: float = 256.0, n_gauss_side: int = 4,
               gauss_slack: float = 1.0) -> np.ndarray:
    g = golden.astype(np.int64)
    c = cand.astype(np.int64)
    diff = g - c
    ad = np.abs(diff)
    n = diff.size
    out_range = float(1 << n_o)
    mae = ad.mean()
    wce = ad.max()
    er = (diff != 0).mean()
    mre = (ad / np.maximum(g, 1)).mean()
    avg = diff.mean()
    acc0 = float(((g == 0) & (c != 0)).sum() == 0)
    edges = gauss_bin_edges(gauss_sigma, n_gauss_side)
    idx = np.searchsorted(edges, diff.astype(np.float64), side="right")
    hist = np.bincount(idx[diff != 0], minlength=len(edges) + 1)
    mass = gauss_bin_mass(gauss_sigma, n_gauss_side)
    gauss_ok = float(np.all(hist <= mass * n * gauss_slack))
    return np.array([100 * mae / out_range, 100 * wce / out_range, 100 * er,
                     100 * mre, 100 * abs(avg) / out_range, acc0, gauss_ok],
                    dtype=np.float32)


def metrics_stderr_np(golden: np.ndarray, cand: np.ndarray,
                      n_o: int) -> np.ndarray:
    """float64 oracle for ``metric_stderr`` (population-variance CLT SEs)."""
    g = golden.astype(np.int64)
    c = cand.astype(np.int64)
    diff = (g - c).astype(np.float64)
    ad = np.abs(diff)
    rel = ad / np.maximum(g, 1)
    n = max(diff.size, 1)
    out_range = float(1 << n_o)
    se = lambda v: np.sqrt(max(np.mean(v * v) - np.mean(v) ** 2, 0.0) / n)
    er_hat = (diff != 0).mean() if diff.size else 0.0
    return np.array([
        100 * se(ad) / out_range,
        0.0,
        100 * np.sqrt(max(er_hat * (1 - er_hat), 0.0) / n),
        100 * se(rel),
        100 * se(diff) / out_range,
        0.0,
        0.0,
    ], dtype=np.float32)

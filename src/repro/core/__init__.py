"""The paper's primary contribution: error-oriented CGP approximation of
arithmetic circuits under COMBINED error constraints (Eq. 8/9), implemented
as a jit/shard_map-distributed JAX system.  See DESIGN.md.
"""
from repro.core.fitness import ConstraintSpec, feasible, fitness
from repro.core.genome import CGPSpec, Genome, random_genome, active_mask
from repro.core.golden import array_multiplier, golden_values, ripple_carry_adder
from repro.core.evolve import EvolveConfig, EvolveResult, evolve, evolve_sharded
from repro.core.search import CircuitRecord, SearchConfig, run_search, run_sweep
from repro.core import metrics, pareto, power, simulate, library

__all__ = [
    "ConstraintSpec", "CGPSpec", "Genome", "EvolveConfig", "EvolveResult",
    "SearchConfig", "CircuitRecord", "array_multiplier", "ripple_carry_adder",
    "golden_values", "random_genome", "active_mask", "feasible", "fitness",
    "evolve", "evolve_sharded", "run_search", "run_sweep",
    "metrics", "pareto", "power", "simulate", "library",
]

"""Activity-based power / area / delay model (DESIGN.md §2).

Replaces the paper's yosys + FreePDK45 synthesis step with an analytic model
computable on-device from the same exhaustive simulation the error metrics use:

    P_dyn(C)  = Σ_{g active}  2·p_g·(1-p_g) · E_sw(type(g)) · f_clk
    P_leak(C) = Σ_{g active}  I_leak(type(g))
    power(C)  = P_dyn + P_leak        (f_clk fixed; constants in gates.py)

``p_g`` is the *exact* signal probability of gate g's output under uniform
inputs, obtained by popcounting the simulated bit-plane — uniform-input
switching activity is the standard vectorless power-estimation model and uses
exactly the information the paper's exhaustive evaluation produces.  Only the
ratio power(C)/power(G) ("relative power") is reported, as in the paper.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gates
from repro.core.genome import CGPSpec, Genome, active_mask, critical_path_ps
from repro.core.simulate import signal_probabilities

F_CLK_GHZ = 1.0  # fixed clock for the dynamic term; cancels in relative power


class CircuitCost(NamedTuple):
    power: jax.Array      # arbitrary units (fJ·GHz + nW)
    area: jax.Array       # um^2
    delay: jax.Array      # ps (critical path over active gates)
    n_active: jax.Array   # active gate count


def circuit_cost(genome: Genome, spec: CGPSpec, wires: jax.Array,
                 n_bits: int) -> CircuitCost:
    """Cost of a candidate from its simulated wire planes.

    Args:
      wires: (n_wires, W) packed simulation (``simulate.simulate_planes``).
      n_bits: valid bits in the planes (cube-slice size).  When the input
        cube is sharded, signal probabilities must be psum-averaged first —
        see ``evolve._eval_candidate`` which passes globally combined p.
    """
    p = signal_probabilities(wires[spec.n_i:], n_bits)  # (n_n,)
    return circuit_cost_from_probs(genome, spec, p)


def circuit_cost_from_probs(genome: Genome, spec: CGPSpec,
                            p: jax.Array,
                            with_delay: bool = True) -> CircuitCost:
    """``with_delay=False`` skips the sequential critical-path scan — the
    Eq. (8) fitness only uses power, and the 400-step delay scan was ~30% of
    the evolve hot loop (EXPERIMENTS.md §Perf hillclimb C1); final
    characterization always computes it."""
    func = genome.nodes[:, 2]
    act = active_mask(genome, spec)[spec.n_i:].astype(jnp.float32)
    e_sw = jnp.asarray(gates.SWITCH_ENERGY_FJ)[func]
    leak = jnp.asarray(gates.LEAKAGE_NW)[func]
    area = jnp.asarray(gates.AREA_UM2)[func]
    activity = 2.0 * p * (1.0 - p)
    p_dyn = (act * activity * e_sw).sum() * F_CLK_GHZ
    p_leak = (act * leak).sum() * 1e-3  # scale leakage below dynamic, as at 45nm
    return CircuitCost(
        power=p_dyn + p_leak,
        area=(act * area).sum(),
        delay=(critical_path_ps(genome, spec) if with_delay
               else jnp.float32(0.0)),
        n_active=act.sum().astype(jnp.int32),
    )

"""Gate set Γ for the linear 1-D CGP used in the paper.

The paper uses standard 2-input/1-output CGP nodes (Sec. III-A, Fig. 3 shows
Γ = {inv, and, or, xor}; the full experiments use the usual 8-function set of
the EvoApprox line of work).  Every gate is represented by a 4-bit truth table
indexed by ``a + 2*b`` so that simulation is a branch-free 4-term mask merge —
this is what lets the Pallas kernel evaluate any gate without control flow.

Power/area/delay constants are a FreePDK45-calibrated analytic proxy (see
DESIGN.md §2 — no RTL synthesis is possible in this container).  Only
*relative* power (vs. the golden circuit) is ever reported, matching the
paper's figures.
"""
from __future__ import annotations

import numpy as np

# Gate codes.  Keep BUF first so that "wire-through" mutations are cheap.
BUF, INV, AND, OR, XOR, NAND, NOR, XNOR = range(8)

GATE_NAMES = ("buf", "inv", "and", "or", "xor", "nand", "nor", "xnor")
N_FUNCS = 8

# 4-bit truth tables, bit k = output for (a, b) with k = a + 2*b.
#                 BUF     INV     AND     OR      XOR     NAND    NOR     XNOR
TRUTH_TABLES = np.array([0b1010, 0b0101, 0b1000, 0b1110, 0b0110, 0b0111, 0b0001, 0b1001],
                        dtype=np.int32)

# all 8 truth tables packed into one 32-bit scalar (4 bits per gate code) so
# Pallas kernels can select a gate's table without capturing a constant array:
#   tt = (TT_PACKED >> (4*func)) & 0xF
TT_PACKED = int(sum(int(t) << (4 * i) for i, t in enumerate(TRUTH_TABLES)))

# Which gates ignore their second input (1-input gates).  Used by the active-set
# computation so that power is not attributed to a dangling fan-in.
ONE_INPUT = np.array([1, 1, 0, 0, 0, 0, 0, 0], dtype=np.int32)

# --- FreePDK45-calibrated analytic constants (per-gate) -----------------------
# Switching energy in fJ per output toggle (proxy: input cap * VDD^2 scale),
# leakage in nW, area in um^2, propagation delay in ps.  Values follow the
# usual static-CMOS transistor-count ordering (INV < NAND/NOR < AND/OR < XOR).
SWITCH_ENERGY_FJ = np.array([1.20, 0.70, 1.40, 1.40, 2.10, 1.00, 1.00, 2.10], dtype=np.float32)
LEAKAGE_NW      = np.array([18.0, 10.0, 22.0, 22.0, 36.0, 16.0, 16.0, 36.0], dtype=np.float32)
AREA_UM2        = np.array([1.06, 0.53, 1.33, 1.33, 2.13, 0.80, 0.80, 2.13], dtype=np.float32)
DELAY_PS        = np.array([18.0, 10.0, 22.0, 24.0, 30.0, 15.0, 18.0, 30.0], dtype=np.float32)


def gate_output_np(func: np.ndarray, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """NumPy oracle for a packed-word gate evaluation (used by tests only)."""
    tt = TRUTH_TABLES[func]
    na, nb = ~a, ~b
    out = np.zeros_like(a)
    masks = (na & nb, a & nb, na & b, a & b)
    for k, m in enumerate(masks):
        sel = -((tt >> k) & 1)  # 0 or -1 (all ones)
        out |= m & sel
    return out

"""Fingerprinted circuit-artifact registry: the evolve → LUT → serve bridge.

Sweep shards (``core.results``) hold evolved genomes + exact characterization;
serving (``launch/serve.py --approx-lut``) needs a product LUT it can trust.
This module is the contract between the two (DESIGN.md §12):

  * ``export_elites`` reads a sweep ``results_dir`` through
    ``SweepResultReader``, picks the per-constraint-group elites (feasible
    rows, certified ones preferred, lowest relative power wins) and
    materializes each as one self-contained ``.npz`` artifact: the
    ``(2^w, 2^w)`` product LUT from ``core.library.multiplier_lut``, the
    genome it was derived from, the exact metric vector + standard errors,
    the constraint thresholds, the grid fingerprint of the sweep that
    produced it, a schema version, and a content digest over all of it.
    A ``registry.json`` manifest indexes the artifacts; every write goes
    through ``checkpoint.store`` (tmp + fsync + rename), so presence is the
    commit marker — a crashed export never leaves a half-written artifact
    under a committed name.
  * ``load_artifact`` is the verify path: it recomputes the content digest
    from the loaded payload AND re-derives the LUT from the shipped genome,
    refusing the artifact on any mismatch — a registry entry that passes
    ``load_artifact(path)`` is guaranteed to be the arithmetic the sweep
    characterized, not a corrupted or hand-edited table.

Digest scheme: sha256 over every payload array's (name, dtype, shape, bytes),
in sorted key order — deterministic across platforms (all payload arrays are
fixed-dtype little-endian numpy), and covering the genome, LUT and metrics
alike, so silent single-byte LUT corruption is caught even before the
genome-replay check.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Sequence

import numpy as np

from repro.checkpoint.store import atomic_save_npz, atomic_write_json
from repro.core import metrics as M

ARTIFACT_SCHEMA_VERSION = 1
REGISTRY = "registry.json"

#: payload keys covered by the content digest (everything except the digest
#: itself); load_artifact refuses artifacts with missing keys
_PAYLOAD_KEYS = (
    "schema_version", "kind", "width", "n_n",
    "lut", "genome_nodes", "genome_outs",
    "metrics", "metrics_stderr", "thresholds",
    "power_rel", "error_mean", "error_std",
    "feasible", "certified", "seed", "gauss_sigma",
    "constraint", "grid_fingerprint", "grid_row",
)


@dataclasses.dataclass(frozen=True)
class ExportPolicy:
    """Elite-selection policy of ``export_elites``.

    Rows are grouped by (constraint description, gauss σ) — one group per
    grid constraint — and within each group ranked certified-first then by
    ascending relative power (the paper's selection rule: the cheapest
    circuit that provably satisfies the constraint).
    """
    top_k: int = 1                  # artifacts per constraint group
    feasible_only: bool = True      # drop constraint-violating rows
    require_certified: bool = False  # hard-require exact-certified metrics


@dataclasses.dataclass
class Artifact:
    """One loaded (and, by default, verified) registry artifact."""
    lut: np.ndarray                 # (2^w, 2^w) int32 product table
    genome_nodes: np.ndarray        # (n_n, 3) int32
    genome_outs: np.ndarray         # (n_o,) int32
    width: int
    kind: str
    n_n: int
    metrics: np.ndarray             # (N_METRICS,) float32
    metrics_stderr: np.ndarray      # (N_METRICS,) float32
    thresholds: np.ndarray          # (N_METRICS,) float32
    power_rel: float
    error_mean: float
    error_std: float
    feasible: bool
    certified: bool
    seed: int
    gauss_sigma: float
    constraint: str
    grid_fingerprint: str
    grid_row: int
    digest: str
    path: str | None = None

    def metric_dict(self) -> dict[str, float]:
        return {n: float(v) for n, v in zip(M.METRIC_NAMES, self.metrics)}


def content_digest(payload: dict[str, np.ndarray]) -> str:
    """sha256 over (name, dtype, shape, bytes) of every payload array in
    sorted key order.  ``digest`` itself is excluded."""
    h = hashlib.sha256()
    for key in sorted(payload):
        if key == "digest":
            continue
        arr = np.ascontiguousarray(payload[key])
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _recompute_lut(nodes: np.ndarray, outs: np.ndarray, width: int,
                   n_n: int, n_o: int) -> np.ndarray:
    """Replay the genome through the simulator: the authoritative LUT."""
    import jax.numpy as jnp
    from repro.core.genome import CGPSpec, Genome
    from repro.core.library import multiplier_lut
    genome = Genome(jnp.asarray(np.asarray(nodes, np.int32)),
                    jnp.asarray(np.asarray(outs, np.int32)))
    return multiplier_lut(genome, CGPSpec(2 * width, n_o, n_n))


def _group_rows(grid: Sequence[dict]) -> dict[tuple, list[int]]:
    """grid-order row indices grouped by (constraint, gauss σ)."""
    groups: dict[tuple, list[int]] = {}
    for i, g in enumerate(grid):
        key = (g["constraint"], float(g.get("gauss_sigma", 0.0)))
        groups.setdefault(key, []).append(i)
    return groups


def export_elites(results_dir: str, out_dir: str,
                  policy: ExportPolicy | None = None, *,
                  width: int | None = None,
                  kind: str | None = None) -> dict:
    """Export per-constraint elite circuits from a sweep as LUT artifacts.

    Args:
      results_dir: a ``SweepResultWriter`` directory (manifest + shards).
      out_dir: registry directory; receives one ``.npz`` per elite plus
        ``registry.json``.  Re-exporting the same sweep is idempotent
        (artifact names include the content digest); a directory already
        holding a DIFFERENT grid's registry is refused.
      policy: elite selection (default ``ExportPolicy()``).
      width/kind: problem geometry overrides for results directories whose
        manifest predates the ``problem`` block (DESIGN.md §12); newer
        manifests carry them and the overrides must agree if given.

    Returns the registry manifest dict (also written to
    ``out_dir/registry.json``).
    """
    from repro.core.results import SweepResultReader
    policy = policy or ExportPolicy()
    reader = SweepResultReader(results_dir)
    problem = reader.manifest.get("problem") or {}
    if width is None:
        width = problem.get("width")
    elif problem.get("width") not in (None, width):
        raise ValueError(f"width={width} contradicts the results manifest "
                         f"(problem.width={problem['width']})")
    if kind is None:
        kind = problem.get("kind", "mul")
    if width is None:
        raise ValueError(
            f"results manifest at {results_dir!r} predates problem metadata "
            f"— pass width= (and kind=) explicitly")
    if kind != "mul":
        raise ValueError(f"LUT artifacts are multiplier deployments; "
                         f"kind={kind!r} is not exportable")

    dims = reader.manifest["dims"]
    s = reader.summary(["parent_nodes", "parent_outs", "metrics",
                        "metrics_stderr", "power_rel", "feasible",
                        "certified_mask", "thresholds", "error_mean",
                        "error_std"])
    grid = reader.manifest["grid"]

    # refuse to mix registries: out_dir may hold THIS grid's export only
    reg_path = os.path.join(out_dir, REGISTRY)
    if os.path.exists(reg_path):
        with open(reg_path) as f:
            have = json.load(f)
        if have.get("grid_fingerprint") != reader.fingerprint:
            raise ValueError(
                f"registry {out_dir!r} holds a different sweep "
                f"(fingerprint {have.get('grid_fingerprint')!r} != "
                f"{reader.fingerprint!r}); use a fresh directory")

    entries = []
    os.makedirs(out_dir, exist_ok=True)
    for (constraint, sigma), rows in sorted(_group_rows(grid).items()):
        cand = [i for i in rows if s["done_mask"][i]]
        if policy.feasible_only:
            cand = [i for i in cand if s["feasible"][i]]
        if policy.require_certified:
            cand = [i for i in cand if s["certified_mask"][i]]
        # certified elites outrank uncertified; power breaks ties; the grid
        # row index makes the order (and thus the registry) deterministic
        cand.sort(key=lambda i: (-int(s["certified_mask"][i]),
                                 float(s["power_rel"][i]), i))
        for i in cand[:policy.top_k]:
            lut = _recompute_lut(s["parent_nodes"][i], s["parent_outs"][i],
                                 width, dims["n_n"], dims["n_o"])
            payload = {
                "schema_version": np.int32(ARTIFACT_SCHEMA_VERSION),
                "kind": np.str_(kind),
                "width": np.int32(width),
                "n_n": np.int32(dims["n_n"]),
                "lut": np.asarray(lut, np.int32),
                "genome_nodes": np.asarray(s["parent_nodes"][i], np.int32),
                "genome_outs": np.asarray(s["parent_outs"][i], np.int32),
                "metrics": np.asarray(s["metrics"][i], np.float32),
                "metrics_stderr": np.asarray(s["metrics_stderr"][i],
                                             np.float32),
                "thresholds": np.asarray(s["thresholds"][i], np.float32),
                "power_rel": np.float32(s["power_rel"][i]),
                "error_mean": np.float32(s["error_mean"][i]),
                "error_std": np.float32(s["error_std"][i]),
                "feasible": np.uint8(s["feasible"][i]),
                "certified": np.uint8(s["certified_mask"][i]),
                "seed": np.int32(grid[i]["seed"]),
                "gauss_sigma": np.float32(sigma),
                "constraint": np.str_(constraint),
                "grid_fingerprint": np.str_(reader.fingerprint),
                "grid_row": np.int32(i),
            }
            digest = content_digest(payload)
            payload["digest"] = np.str_(digest)
            name = f"{kind}{width}_row{i:05d}_{digest[:12]}.npz"
            atomic_save_npz(os.path.join(out_dir, name), payload)
            entries.append({
                "file": name, "digest": digest, "grid_row": int(i),
                "constraint": constraint, "seed": int(grid[i]["seed"]),
                "gauss_sigma": float(sigma),
                "power_rel": float(s["power_rel"][i]),
                "feasible": bool(s["feasible"][i]),
                "certified": bool(s["certified_mask"][i]),
                "metrics": {n: float(v) for n, v in
                            zip(M.METRIC_NAMES, s["metrics"][i])},
            })

    registry = {
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "grid_fingerprint": reader.fingerprint,
        "problem": {"width": int(width), "kind": kind,
                    "n_n": int(dims["n_n"])},
        "policy": dataclasses.asdict(policy),
        "source_results_dir": os.path.abspath(results_dir),
        "artifacts": entries,
    }
    atomic_write_json(reg_path, registry)
    return registry


def load_artifact(path: str, *, verify: bool = True,
                  expect_fingerprint: str | None = None) -> Artifact:
    """Load one artifact npz; verify its digest and replay its genome.

    ``verify=True`` (the default, and what serving uses) recomputes the
    content digest over the loaded payload and re-derives the LUT from the
    shipped genome through the circuit simulator — any mismatch (bit rot,
    truncation, a hand-edited LUT, a genome/LUT swap) raises ``ValueError``.
    ``expect_fingerprint`` additionally pins the sweep the artifact must
    come from.
    """
    with np.load(path) as z:
        missing = [k for k in _PAYLOAD_KEYS if k not in z]
        if missing:
            raise ValueError(f"artifact {path!r} missing keys {missing}")
        payload = {k: np.asarray(z[k]) for k in z.files}
    ver = int(payload["schema_version"])
    if ver > ARTIFACT_SCHEMA_VERSION:
        raise ValueError(f"artifact schema v{ver} newer than supported "
                         f"v{ARTIFACT_SCHEMA_VERSION}: {path!r}")
    stored_digest = str(payload.get("digest", ""))
    art = Artifact(
        lut=payload["lut"].astype(np.int32),
        genome_nodes=payload["genome_nodes"],
        genome_outs=payload["genome_outs"],
        width=int(payload["width"]),
        kind=str(payload["kind"]),
        n_n=int(payload["n_n"]),
        metrics=payload["metrics"],
        metrics_stderr=payload["metrics_stderr"],
        thresholds=payload["thresholds"],
        power_rel=float(payload["power_rel"]),
        error_mean=float(payload["error_mean"]),
        error_std=float(payload["error_std"]),
        feasible=bool(payload["feasible"]),
        certified=bool(payload["certified"]),
        seed=int(payload["seed"]),
        gauss_sigma=float(payload["gauss_sigma"]),
        constraint=str(payload["constraint"]),
        grid_fingerprint=str(payload["grid_fingerprint"]),
        grid_row=int(payload["grid_row"]),
        digest=stored_digest,
        path=path,
    )
    if expect_fingerprint is not None \
            and art.grid_fingerprint != expect_fingerprint:
        raise ValueError(
            f"artifact {path!r} comes from grid "
            f"{art.grid_fingerprint[:12]}…, expected "
            f"{expect_fingerprint[:12]}… — wrong sweep")
    if verify:
        want = content_digest(payload)
        if want != stored_digest:
            raise ValueError(f"artifact {path!r} digest mismatch "
                             f"(stored {stored_digest[:12]}…, content "
                             f"{want[:12]}…) — refusing corrupt artifact")
        replayed = _recompute_lut(art.genome_nodes, art.genome_outs,
                                  art.width, art.n_n,
                                  art.genome_outs.shape[0])
        if not np.array_equal(replayed, art.lut):
            raise ValueError(f"artifact {path!r} LUT does not match its "
                             f"genome replay — refusing tampered artifact")
    return art


def load_registry(registry_dir: str) -> dict:
    path = os.path.join(registry_dir, REGISTRY)
    if not os.path.exists(path):
        raise FileNotFoundError(f"no {REGISTRY} in {registry_dir!r} "
                                f"(run export_elites first)")
    with open(path) as f:
        return json.load(f)


def verify_registry(registry_dir: str) -> list[Artifact]:
    """Fully verify every registry entry (digest + genome replay + the
    registry's own digest index).  Returns the loaded artifacts; raises on
    the first failure."""
    reg = load_registry(registry_dir)
    arts = []
    for entry in reg["artifacts"]:
        art = load_artifact(os.path.join(registry_dir, entry["file"]),
                            verify=True,
                            expect_fingerprint=reg["grid_fingerprint"])
        if art.digest != entry["digest"]:
            raise ValueError(f"registry digest for {entry['file']} "
                             f"({entry['digest'][:12]}…) != artifact digest "
                             f"({art.digest[:12]}…)")
        arts.append(art)
    return arts


def select_artifact(registry_dir: str, *, constraint: str | None = None,
                    certified_only: bool = False) -> str:
    """Pick one artifact path from a registry: lowest relative power among
    feasible entries (certified entries outrank uncertified), optionally
    filtered to constraints containing ``constraint`` as a substring."""
    reg = load_registry(registry_dir)
    cand = [e for e in reg["artifacts"] if e["feasible"]]
    if constraint is not None:
        cand = [e for e in cand if constraint in e["constraint"]]
    if certified_only:
        cand = [e for e in cand if e["certified"]]
    if not cand:
        raise ValueError(f"no matching artifact in {registry_dir!r} "
                         f"(constraint={constraint!r}, "
                         f"certified_only={certified_only})")
    best = min(cand, key=lambda e: (-int(e["certified"]), e["power_rel"],
                                    e["grid_row"]))
    return os.path.join(registry_dir, best["file"])


def resolve_artifact(path: str, *, verify: bool = True) -> Artifact:
    """Load an artifact from either a direct ``.npz`` path or a registry
    directory (best entry per ``select_artifact``) — the form ``serve
    --approx-lut`` accepts."""
    if os.path.isdir(path):
        path = select_artifact(path)
    return load_artifact(path, verify=verify)

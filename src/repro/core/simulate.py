"""Bit-packed exhaustive circuit simulation (paper Sec. IV).

The paper evaluates every candidate on all 2^n input combinations using 64-bit
bitwise vectorization on Xeon cores.  The TPU-native formulation packs the
input cube into int32 *lanes* (the VPU's native word): wire ``w``'s value over
the whole cube is a bit-plane of ``2^n_i`` bits stored as ``(n_words,)`` int32.
Simulation walks the node array once, doing W-wide branch-free truth-table
merges — this module is the pure-jnp reference path; ``repro.kernels.cgp_sim``
is the fused Pallas kernel with the same semantics (tested allclose).

Input-space sharding: every function below takes the *word slice* to simulate,
so a mesh axis can split the cube (each shard passes its own ``input_planes``
slice and psums the metric partials — see ``core.evolve``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gates
from repro.core.genome import CGPSpec, Genome

I32 = jnp.int32


@functools.lru_cache(maxsize=32)
def input_planes_np(n_i: int) -> np.ndarray:
    """(n_i, n_words) int32 bit-planes of the exhaustive input cube.

    Bit ``l`` of word ``w`` in plane ``i`` is bit ``i`` of the input index
    ``x = 32*w + l``.  Cubes smaller than one word are tiled to 32 lanes —
    all normalized metrics and signal probabilities are invariant under
    whole-cube replication, so packing stays exact for tiny test circuits.
    """
    n = 1 << n_i
    xs = np.arange(max(n, 32), dtype=np.uint64) % np.uint64(n)
    planes = []
    for i in range(n_i):
        bits = ((xs >> np.uint64(i)) & np.uint64(1)).astype(np.uint32)
        words = bits.reshape(-1, 32)
        packed = (words << np.arange(32, dtype=np.uint32)[None, :]).sum(
            axis=1, dtype=np.uint32)
        planes.append(packed)
    return np.stack(planes).astype(np.int32)  # two's complement reinterpret


def input_planes(n_i: int) -> jax.Array:
    return jnp.asarray(input_planes_np(n_i))


def gate_eval(func: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Branch-free packed gate evaluation via 4-term truth-table merge."""
    tt = jnp.asarray(gates.TRUTH_TABLES)[func]
    na, nb = ~a, ~b
    m0, m1, m2, m3 = na & nb, a & nb, na & b, a & b
    s = lambda k: -((tt >> k) & 1)  # 0 or -1 mask
    return (m0 & s(0)) | (m1 & s(1)) | (m2 & s(2)) | (m3 & s(3))


def simulate_planes(genome: Genome, spec: CGPSpec,
                    in_planes: jax.Array) -> jax.Array:
    """Simulate all wires over a (possibly sharded) slice of the input cube.

    Args:
      in_planes: (n_i, W) int32 input bit-planes (W words of the cube slice).
    Returns:
      (n_wires, W) int32 — every wire's bit-plane (inputs first, then nodes).
    """
    n_i, n_n = spec.n_i, spec.n_n
    W = in_planes.shape[-1]
    wires0 = jnp.zeros((spec.n_wires, W), dtype=I32).at[:n_i].set(in_planes)

    def step(wires, k):
        node = genome.nodes[k]
        a = wires[node[0]]
        b = wires[node[1]]
        out = gate_eval(node[2], a, b)
        return wires.at[n_i + k].set(out), None

    wires, _ = jax.lax.scan(step, wires0, jnp.arange(n_n))
    return wires


def output_planes(genome: Genome, spec: CGPSpec,
                  in_planes: jax.Array) -> jax.Array:
    """(n_o, W) packed primary-output planes."""
    wires = simulate_planes(genome, spec, in_planes)
    return wires[genome.outs]


def unpack_values(out_planes: jax.Array) -> jax.Array:
    """Decode packed output planes to per-input integers.

    Args:
      out_planes: (n_o, W) int32.
    Returns:
      (W*32,) int32 — int(f(x)) for every input x in this cube slice.
    """
    n_o, W = out_planes.shape
    lanes = jnp.arange(32, dtype=I32)
    # (n_o, W, 32) bits
    bits = (out_planes[:, :, None] >> lanes[None, None, :]) & 1
    weights = (jnp.int32(1) << jnp.arange(n_o, dtype=I32))  # n_o < 31 assumed
    vals = jnp.tensordot(weights, bits, axes=[[0], [0]])
    return vals.reshape(-1)


def simulate_values(genome: Genome, spec: CGPSpec,
                    in_planes: jax.Array | None = None) -> jax.Array:
    """int(f_C(x)) over the input cube slice (default: full cube)."""
    if in_planes is None:
        in_planes = input_planes(spec.n_i)
    return unpack_values(output_planes(genome, spec, in_planes))


def signal_probabilities(wires: jax.Array, n_bits: int | None = None) -> jax.Array:
    """Exact P(wire = 1) under uniform inputs, from popcounts of bit-planes.

    Args:
      wires: (n_wires, W) packed planes.
      n_bits: number of valid bits in the planes.  Defaults to W*32, which is
        correct even for sub-word cubes tiled to 32 lanes (``input_planes``):
        replication multiplies popcount and bit count alike.  Passing the
        un-tiled cube size for a tiled plane would overestimate p (beyond 1),
        driving the switching activity 2p(1-p) negative.
    """
    pop = jax.lax.population_count(wires.view(jnp.uint32)).astype(jnp.float32)
    if n_bits is None:
        n_bits = wires.shape[-1] * 32
    return pop.sum(axis=-1) / float(n_bits)


def simulate_values_np(genome: Genome, spec: CGPSpec) -> np.ndarray:
    """Pure-NumPy gate-by-gate oracle (slow; tests only)."""
    nodes = np.asarray(genome.nodes)
    outs = np.asarray(genome.outs)
    n = 1 << spec.n_i
    xs = np.arange(n, dtype=np.int64)
    wires = np.zeros((spec.n_wires, n), dtype=np.int64)
    for i in range(spec.n_i):
        wires[i] = (xs >> i) & 1
    tt = gates.TRUTH_TABLES
    for k in range(spec.n_n):
        a, b, f = nodes[k]
        idx = wires[a] + 2 * wires[b]
        wires[spec.n_i + k] = (tt[f] >> idx) & 1
    vals = np.zeros(n, dtype=np.int64)
    for o in range(spec.n_o):
        vals += wires[outs[o]] << o
    return vals.astype(np.int32)

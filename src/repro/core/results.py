"""Streaming sweep-results layer: on-disk shard spill + lazy read-back.

``SweepResult`` used to be the only results surface of the batched sweep
engine (``core.sweep``): every per-run summary AND every per-generation
history lived in host RAM for the whole grid.  At the paper's scale (~27k
runs × thousands of generations × ``N_METRICS`` floats) the histories alone
are tens of GB — with the fused (runs × λ) kernel the evaluation side is no
longer the bottleneck, host-side result handling is.  This module moves the
results path to disk:

  * ``SweepResultWriter`` — called by ``sweep.run_sweep_batched`` after every
    finished chunk; commits the chunk's rows as ONE append-only ``.npz``
    shard (atomic tmp + rename, presence == committed).  Shards are run-major
    and named by their execution-order span, so a re-run of the same grid
    overwrites a shard with bit-identical bytes instead of duplicating rows.
  * ``SweepResultReader`` — lazily iterates shards.  Per-run summary columns
    (``(n_runs,)`` / ``(n_runs, N_METRICS)``) are tiny and are scattered back
    to grid order on demand; per-generation histories are only ever yielded
    one shard at a time (``iter_history``), so peak host memory stays
    independent of grid size.  ``correlations()`` / ``fronts()`` feed
    ``core.pareto`` with exactly the arrays the in-RAM path would build —
    results are bit-identical.

Schema: a ``manifest.json`` (written once, atomically) pins the grid
fingerprint (same identity ``checkpoint/store`` checkpoints are guarded by),
a schema fingerprint (field names/dtypes/shapes + version), the history mode
and the chunk size.  The chunk size is pinned because shard spans are the
deterministic ``sweep.plan_chunks`` partition of the σ-grouped execution
order — resuming with a different chunk size would produce overlapping
spans, so the writer refuses it.

The shard set doubles as the sweep's resume state: ``restore`` scatters the
committed spans back into the driver's summary buffers, so a ``results_dir``
sweep resumes mid-grid even without a ``checkpoint_dir`` (and, because shards
commit every chunk while checkpoints commit every ``checkpoint_every``
chunks, shards are never staler than the checkpoint).

Multi-pod execution (DESIGN.md §6): the manifest additionally pins ``n_pods``
and the full deterministic ``chunk_spans`` plan, and the chunk plan is
round-robin partitioned across pods (``pod_partition``).  Each pod commits
only its own spans, so a partially-run multi-pod directory holds a UNION of
per-pod prefixes — committed coverage is computed per pod
(``pod_prefix_spans``) instead of as one global contiguous prefix, and
single-pod directories are the ``n_pods=1`` special case of the same rule.
Pods share nothing at runtime beyond this one-time manifest: span names,
shard bytes and the manifest content are all deterministic functions of the
fingerprinted grid, so concurrent creation by several pods is idempotent.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import sys
import zipfile
from typing import Iterator, Sequence

import numpy as np

from repro.checkpoint.store import atomic_save_npz, atomic_write_json
from repro.core import metrics as M

SCHEMA_VERSION = 3  # v3: + certified_mask summary column (DESIGN.md §10)
MANIFEST = "manifest.json"
HISTORY_MODES = ("none", "summary", "full")
_SHARD_RE = re.compile(r"^shard_(\d{8})_(\d{8})\.npz$")

#: summary fields present in every shard: name -> (trailing shape spec, dtype)
#: (leading axis is always the row axis; symbolic dims are resolved against
#: the manifest at write/read time)
SUMMARY_FIELDS = {
    "grid_rows": ((), "int32"),            # grid-order index of each row
    "thresholds": (("n_metrics",), "float32"),
    "parent_nodes": (("n_n", 3), "int32"),
    "parent_outs": (("n_o",), "int32"),
    "best_nodes": (("n_n", 3), "int32"),
    "best_outs": (("n_o",), "int32"),
    "best_fit": ((), "float32"),
    "metrics": (("n_metrics",), "float32"),
    # per-metric standard errors (DESIGN.md §9): zeros for exhaustive grids,
    # CLT estimates for sampled ones.  Part of SCHEMA_VERSION 2 — pre-§9
    # shard directories carry a different schema fingerprint and cannot be
    # extended by this code (re-run the sweep to migrate).
    "metrics_stderr": (("n_metrics",), "float32"),
    "power_rel": ((), "float32"),
    "feasible": ((), "uint8"),
    # exact-certification flag (DESIGN.md §10): 1 when the row's error
    # metrics are EXACT over the full cube (exhaustive census, or sampled +
    # escalated through ``core.certify``), 0 for uncertified sampled
    # estimates.  Part of SCHEMA_VERSION 3; v2 directories are READ with a
    # zero default (``READ_DEFAULTS``) but cannot be extended by this writer.
    "certified_mask": ((), "uint8"),
    "error_mean": ((), "float32"),
    "error_std": ((), "float32"),
}

#: summary fields absent from older schema versions the reader still
#: accepts, keyed by manifest version: reads leave the buffer's dtype-zero
#: default in place (certified_mask=0 — nothing in a pre-§10 directory was
#: escalated to the exact tier).
READ_DEFAULTS = {2: frozenset({"certified_mask"})}
MIN_READ_VERSION = min(READ_DEFAULTS, default=SCHEMA_VERSION)

#: per-generation history fields, present when ``keep_history != "none"``
HISTORY_FIELDS = {
    "hist_power_rel": (("gens",), "float32"),
    "hist_fit": (("gens",), "float32"),
    "hist_metrics": (("gens", "n_metrics"), "float32"),
}


def normalize_history_mode(keep_history) -> str:
    """Map the legacy bool knob onto the mode string (True -> "full",
    False -> "none"); validate strings against ``HISTORY_MODES``."""
    if keep_history is True:
        return "full"
    if keep_history is False:
        return "none"
    if keep_history not in HISTORY_MODES:
        raise ValueError(
            f"keep_history must be one of {HISTORY_MODES} (or a legacy "
            f"bool), got {keep_history!r}")
    return keep_history


def shard_fields(keep_history: str) -> dict:
    """The shard schema of a history mode: summary always, histories on disk
    for both "summary" and "full" (the modes only differ in what the driver
    keeps in RAM)."""
    fields = dict(SUMMARY_FIELDS)
    if keep_history != "none":
        fields.update(HISTORY_FIELDS)
    return fields


def schema_fingerprint(keep_history: str, dims: dict[str, int]) -> str:
    """Identity of the shard layout: version + field names/shapes/dtypes +
    the resolved symbolic dims.  Stored in the manifest next to the grid
    fingerprint; a mismatch means the directory holds shards this code (or
    this grid geometry) cannot extend."""
    ident = {
        "version": SCHEMA_VERSION,
        "fields": {k: [list(s), d] for k, (s, d)
                   in sorted(shard_fields(keep_history).items())},
        "dims": {k: int(v) for k, v in sorted(dims.items())},
    }
    return hashlib.sha256(
        json.dumps(ident, sort_keys=True).encode()).hexdigest()


def _shard_name(start: int, end: int) -> str:
    return f"shard_{start:08d}_{end:08d}.npz"


def _scan_spans(results_dir: str) -> list[tuple[int, int]]:
    """Committed shard spans, sorted by start (atomic rename => presence is
    the commit marker)."""
    spans = []
    for name in os.listdir(results_dir):
        if m := _SHARD_RE.match(name):
            spans.append((int(m.group(1)), int(m.group(2))))
    return sorted(spans)


def _prefix_spans(spans: Sequence[tuple[int, int]]) -> list[tuple[int, int]]:
    """The contiguous-from-zero prefix of a sorted span list.  Orphans past a
    gap are unreachable by a resumed single-pod sweep's skip logic and are
    ignored (and deterministically overwritten when the sweep gets there).
    Fallback coverage rule for directories whose manifest predates the
    ``chunk_spans`` plan; plan-pinned directories use ``pod_prefix_spans``."""
    out, want = [], 0
    for start, end in spans:
        if start != want:
            break
        out.append((start, end))
        want = end
    return out


def pod_partition(chunk_spans: Sequence[tuple[int, int]],
                  n_pods: int) -> list[list[tuple[int, int]]]:
    """Round-robin assignment of execution-order chunk spans to pods.

    Pod ``p`` owns ``chunk_spans[p::n_pods]`` — deterministic from the plan
    alone (no coordination), and interleaved so σ-grouped plans spread each
    σ's chunks across pods instead of handing one pod a whole σ block.
    """
    if n_pods < 1:
        raise ValueError(f"n_pods must be >= 1, got {n_pods}")
    return [list(chunk_spans[p::n_pods]) for p in range(n_pods)]


def pod_prefix_spans(committed: Sequence[tuple[int, int]],
                     chunk_spans: Sequence[tuple[int, int]],
                     n_pods: int) -> list[tuple[int, int]]:
    """Committed coverage of a pod-partitioned plan: the union over pods of
    each pod's contiguous committed prefix OF ITS OWN span sequence, sorted.

    This is the multi-pod generalization of ``_prefix_spans`` (to which it
    reduces for ``n_pods=1``): pods commit independently, so the directory
    may cover e.g. pod 1's first three chunks while pod 0 has one — a state
    with global gaps that is still an exact per-pod resume point.  Spans past
    a gap in a pod's OWN sequence are orphans (ignored; deterministically
    overwritten with identical bytes when that pod gets there), exactly like
    the single-pod rule.
    """
    have = set(map(tuple, committed))
    out: list[tuple[int, int]] = []
    for pod_seq in pod_partition([tuple(s) for s in chunk_spans], n_pods):
        for span in pod_seq:
            if span not in have:
                break
            out.append(span)
    return sorted(out)


class SweepResultWriter:
    """Append-only shard writer for one fingerprinted grid.

    Created by ``sweep.run_sweep_batched`` when ``SweepConfig.results_dir``
    is set.  ``write_chunk`` commits one chunk of run-major rows; ``restore``
    is the resume path (scatter the committed coverage back into the summary
    buffers).  Opening a directory that holds a DIFFERENT grid (or the same
    grid with a different chunk size / history mode / pod count) raises —
    pass ``on_mismatch="reset"`` to wipe and restart it instead (the figure
    pipeline namespaces directories by fingerprint, so it never needs to).

    Multi-pod sweeps hand every pod's writer the same ``chunk_spans`` plan
    and ``n_pods``; ``pod_spans`` is the per-pod span filter (which chunks
    this pod owns) and committed coverage is the union of per-pod prefixes
    (``pod_prefix_spans``) rather than one global prefix.
    """

    def __init__(self, results_dir: str, *, grid_fingerprint: str,
                 grid_meta: list[dict], n_runs: int, gens: int,
                 n_n: int, n_o: int, keep_history: str, chunk_size: int,
                 chunk_spans: Sequence[tuple[int, int]] | None = None,
                 n_pods: int = 1, problem_meta: dict | None = None,
                 on_mismatch: str = "error"):
        self.results_dir = results_dir
        keep_history = normalize_history_mode(keep_history)
        dims = {"gens": gens, "n_metrics": M.N_METRICS,
                "n_n": n_n, "n_o": n_o}
        manifest = {
            "schema_version": SCHEMA_VERSION,
            "grid_fingerprint": grid_fingerprint,
            "schema_fingerprint": schema_fingerprint(keep_history, dims),
            "keep_history": keep_history,
            "chunk_size": int(chunk_size),
            "n_pods": int(n_pods),
            "chunk_spans": ([[int(s), int(e)] for s, e in chunk_spans]
                            if chunk_spans is not None else None),
            "n_runs": int(n_runs),
            "dims": dims,
            "metric_names": list(M.METRIC_NAMES),
            # problem geometry (width/kind) so downstream consumers — the
            # artifact registry (core.artifacts, DESIGN.md §12) — can rebuild
            # LUTs from genomes without out-of-band knowledge.  Informational:
            # not part of the mismatch check (the grid fingerprint already
            # covers the problem), absent (None) for writers that replay raw
            # buffers without a SearchConfig.
            "problem": problem_meta,
            "grid": grid_meta,
        }
        os.makedirs(results_dir, exist_ok=True)
        path = os.path.join(results_dir, MANIFEST)
        if os.path.exists(path):
            with open(path) as f:
                have = json.load(f)
            keys = ("grid_fingerprint", "schema_fingerprint", "chunk_size",
                    "keep_history", "n_runs", "schema_version", "n_pods")
            # pre-pod manifests carry no pod fields; they are single-pod
            defaults = {"n_pods": 1}
            diff = [k for k in keys
                    if have.get(k, defaults.get(k)) != manifest[k]]
            if diff:
                if on_mismatch != "reset":
                    raise ValueError(
                        f"results_dir {results_dir!r} holds a different "
                        f"sweep (mismatched: {diff}); use a fresh directory "
                        f"or on_mismatch='reset'")
                for name in os.listdir(results_dir):
                    p = os.path.join(results_dir, name)
                    shutil.rmtree(p) if os.path.isdir(p) else os.remove(p)
                atomic_write_json(path, manifest)
            else:
                if manifest["chunk_spans"] is None and have.get(
                        "chunk_spans"):
                    # reopened without a plan: keep the pinned one (the plan
                    # is a deterministic function of the matched fingerprint
                    # + chunk_size, so it cannot disagree with this sweep)
                    manifest["chunk_spans"] = have["chunk_spans"]
                if manifest["problem"] is None and have.get("problem"):
                    # reopened by a problem-blind writer: keep the pinned
                    # geometry (same fingerprint => same problem)
                    manifest["problem"] = have["problem"]
                if any(k not in have
                       for k in ("n_pods", "chunk_spans", "problem")):
                    # matching pre-pod / pre-§12 directory: one-time
                    # idempotent upgrade
                    atomic_write_json(path, manifest)
        else:
            atomic_write_json(path, manifest)
        self.manifest = manifest
        self._fields = shard_fields(keep_history)
        self._dims = dims

    def spans(self) -> list[tuple[int, int]]:
        """All committed shard spans (execution order), sorted."""
        return _scan_spans(self.results_dir)

    def pod_spans(self, pod_index: int) -> list[tuple[int, int]]:
        """The span filter of one pod: the ordered slice of the chunk plan
        that pod ``pod_index`` owns (requires a ``chunk_spans`` plan)."""
        plan = self.manifest.get("chunk_spans")
        if plan is None:
            raise ValueError("writer opened without a chunk_spans plan")
        parts = pod_partition([tuple(s) for s in plan],
                              self.manifest["n_pods"])
        return parts[pod_index]

    def live_spans(self) -> list[tuple[int, int]]:
        """Committed coverage: the union of per-pod committed prefixes of the
        manifest's chunk plan (global contiguous prefix when no plan is
        pinned — pre-pod directories)."""
        committed = self.spans()
        plan = self.manifest.get("chunk_spans")
        if plan is None:
            return _prefix_spans(committed)
        return pod_prefix_spans(committed, [tuple(s) for s in plan],
                                self.manifest["n_pods"])

    def coverage(self) -> int:
        """Number of runs covered by the committed per-pod prefixes."""
        return sum(end - start for start, end in self.live_spans())

    #: load/scatter failures ``restore`` treats as a damaged shard rather
    #: than a bug: zero-byte or truncated files (BadZipFile/EOFError/
    #: OSError/ValueError from ``np.load``), a missing or malformed member
    #: (KeyError), out-of-range grid rows (IndexError)
    _CORRUPT_ERRORS = (OSError, EOFError, ValueError, KeyError, IndexError,
                       zipfile.BadZipFile)

    def _quarantine(self, path: str, err: Exception) -> None:
        """Move a damaged shard aside (``<name>.corrupt`` — no longer a
        committed span) so the sweep re-runs and re-commits it instead of
        crashing at restore time (DESIGN.md §11: a crash between the data
        write and the directory fsync on a pre-fsync layer can legitimately
        leave a truncated file under the committed name)."""
        quarantined = path + ".corrupt"
        os.replace(path, quarantined)
        print(f"[results] quarantined damaged shard {path} -> "
              f"{quarantined}: {type(err).__name__}: {err}",
              file=sys.stderr, flush=True)

    def restore(self, bufs: dict[str, np.ndarray]) -> list[tuple[int, int]]:
        """Scatter the committed coverage into grid-order buffers in place
        (only keys present in ``bufs`` are touched) and return the covered
        spans — the sweep's resume point (each pod skips its own committed
        prefix; other pods' spans pre-fill the result buffers).

        A zero-byte/truncated/unreadable shard is quarantined (renamed +
        logged, see ``_quarantine``), its span drops out of the committed
        coverage, and the scatter restarts over the recomputed coverage —
        re-scattering a healthy shard is idempotent.
        """
        while True:
            live = self.live_spans()
            path = None
            try:
                for start, end in live:
                    path = self._path(start, end)
                    with np.load(path) as z:
                        rows = z["grid_rows"]
                        for key in bufs:
                            if key in z:
                                bufs[key][rows] = z[key]
            except self._CORRUPT_ERRORS as e:
                self._quarantine(path, e)
                continue
            return live

    def write_chunk(self, span: tuple[int, int],
                    rows: dict[str, np.ndarray]) -> str:
        """Atomically commit one chunk's rows as a shard.

        ``span`` is the [start, end) execution-order span from
        ``sweep.plan_chunks``; ``rows`` must hold exactly the schema's fields
        with ``end - start`` rows each, including ``grid_rows`` (the
        grid-order index of each row — σ-grouped execution permutes the
        grid, shards record the mapping).
        """
        start, end = span
        n = end - start
        if set(rows) != set(self._fields):
            raise ValueError(f"shard fields {sorted(rows)} != schema "
                             f"{sorted(self._fields)}")
        out = {}
        for key, (shape, dtype) in self._fields.items():
            want = (n,) + tuple(self._dims[d] if isinstance(d, str) else d
                                for d in shape)
            arr = np.ascontiguousarray(rows[key], dtype=dtype)
            if arr.shape != want:
                raise ValueError(f"{key}: shape {arr.shape} != {want}")
            out[key] = arr
        path = self._path(start, end)
        atomic_save_npz(path, out)
        return path

    def _path(self, start: int, end: int) -> str:
        return os.path.join(self.results_dir, _shard_name(start, end))


class SweepResultReader:
    """Lazy view over a committed shard set.

    Summary columns are materialized on demand in grid order (constraints
    outer, seeds inner — a few floats per run, cheap at any grid size);
    per-generation histories are only ever surfaced one shard at a time.
    ``correlations()`` / ``fronts()`` are bit-identical to calling
    ``pareto.metric_correlations`` / ``pareto.sweep_fronts`` on the in-RAM
    ``SweepResult`` of the same grid.

    Attributes:
      manifest:     the writer's manifest dict (fingerprints, dims, grid).
      n_runs:       grid size (completed or not).
      gens:         generations per run (history row length).
      keep_history: "none" | "summary" | "full" — "none" shards carry no
                    history fields.
      fingerprint:  the grid fingerprint (``sweep.grid_fingerprint``).
    """

    def __init__(self, results_dir: str):
        self.results_dir = results_dir
        path = os.path.join(results_dir, MANIFEST)
        if not os.path.exists(path):
            raise FileNotFoundError(f"no results manifest at {path!r}")
        with open(path) as f:
            self.manifest = json.load(f)
        ver = self.manifest["schema_version"]
        if not MIN_READ_VERSION <= ver <= SCHEMA_VERSION:
            raise ValueError(
                f"shard schema v{ver} not readable by "
                f"v{SCHEMA_VERSION} reader "
                f"(accepts v{MIN_READ_VERSION}..v{SCHEMA_VERSION})")
        self.schema_version: int = ver
        # fields this directory's shards predate; reads keep the dtype-zero
        # default in their place (e.g. certified_mask=0 for v2 shards)
        self._absent: frozenset = READ_DEFAULTS.get(ver, frozenset())
        self.n_runs: int = self.manifest["n_runs"]
        self.gens: int = self.manifest["dims"]["gens"]
        self.keep_history: str = self.manifest["keep_history"]
        self.fingerprint: str = self.manifest["grid_fingerprint"]
        self.metric_names: list[str] = self.manifest["metric_names"]
        # pre-pod manifests pin neither a pod count nor the chunk plan
        self.n_pods: int = self.manifest.get("n_pods", 1)
        # problem geometry for LUT reconstruction (core.artifacts); None
        # for directories written before DESIGN.md §12
        self.problem: dict | None = self.manifest.get("problem")

    # -- shard-level access -------------------------------------------------

    def spans(self) -> list[tuple[int, int]]:
        """Committed shard spans, execution order: the union of per-pod
        committed prefixes of the manifest's chunk plan — a mid-sweep
        multi-pod directory legitimately has global gaps (DESIGN.md §6).
        Falls back to the global contiguous prefix for pre-pod manifests
        without a pinned plan."""
        committed = _scan_spans(self.results_dir)
        plan = self.manifest.get("chunk_spans")
        if plan is None:
            return _prefix_spans(committed)
        return pod_prefix_spans(committed, [tuple(s) for s in plan],
                                self.n_pods)

    @property
    def completed(self) -> int:
        """Runs covered by the committed per-pod prefixes."""
        return sum(end - start for start, end in self.spans())

    def done_mask(self) -> np.ndarray:
        """(n_runs,) bool, grid order — rows with committed results."""
        mask = np.zeros(self.n_runs, dtype=bool)
        for _, rows in self.iter_shards(fields=("grid_rows",)):
            mask[rows["grid_rows"]] = True
        return mask

    def iter_shards(self, fields: Sequence[str] | None = None
                    ) -> Iterator[tuple[tuple[int, int], dict]]:
        """Yield ``(span, {field: (rows, ...) array})`` per committed shard,
        loading only ``fields`` (default: every field in the shard) — the
        constant-memory access path."""
        for start, end in self.spans():
            path = os.path.join(self.results_dir, _shard_name(start, end))
            with np.load(path) as z:
                # drop fields the directory's schema version predates — the
                # caller's pre-zeroed buffers keep the documented default
                keys = (z.files if fields is None
                        else [k for k in fields if k not in self._absent])
                yield (start, end), {k: z[k] for k in keys}

    def iter_history(self) -> Iterator[tuple[np.ndarray, dict]]:
        """Yield ``(grid_rows, {hist_*: (rows, gens, ...)})`` per shard.

        Raises if the shard set was written with ``keep_history="none"``.
        Peak memory is one chunk of history, independent of grid size.
        """
        if self.keep_history == "none":
            raise ValueError('shards written with keep_history="none" hold '
                             'no per-generation histories')
        fields = ("grid_rows",) + tuple(HISTORY_FIELDS)
        for _, rows in self.iter_shards(fields=fields):
            yield rows["grid_rows"], {k: rows[k] for k in HISTORY_FIELDS}

    # -- grid-order summary -------------------------------------------------

    def summary(self, fields: Sequence[str] | None = None
                ) -> dict[str, np.ndarray]:
        """Materialize summary columns in grid order.

        Args:
          fields: summary field names (default: all of ``SUMMARY_FIELDS``
            except ``grid_rows``).  History fields are refused — use
            ``iter_history``.
        Returns:
          {field: (n_runs, ...) array} plus ``"done_mask"``: (n_runs,) bool.
          Rows not yet committed are zero.
        """
        if fields is None:
            fields = [k for k in SUMMARY_FIELDS if k != "grid_rows"]
        bad = set(fields) - set(SUMMARY_FIELDS)
        if bad:
            raise ValueError(f"not summary fields: {sorted(bad)} "
                             f"(histories go through iter_history)")
        dims = self.manifest["dims"]
        out, mask = {}, np.zeros(self.n_runs, dtype=bool)
        for key in fields:
            shape, dtype = SUMMARY_FIELDS[key]
            trail = tuple(dims[d] if isinstance(d, str) else d for d in shape)
            out[key] = np.zeros((self.n_runs,) + trail, dtype=dtype)
        for _, rows in self.iter_shards(fields=("grid_rows",) + tuple(fields)):
            idx = rows["grid_rows"]
            mask[idx] = True
            for key in fields:
                if key in rows:  # else: version-absent, zero default stands
                    out[key][idx] = rows[key]
        out["done_mask"] = mask
        return out

    def records(self) -> list:
        """Rebuild grid-order ``search.CircuitRecord`` rows for every
        committed run — the same list ``search.run_sweep`` returns."""
        from repro.core.search import CircuitRecord
        s = self.summary(["parent_nodes", "parent_outs", "metrics",
                          "metrics_stderr", "power_rel", "feasible",
                          "certified_mask", "error_mean", "error_std"])
        grid = self.manifest["grid"]
        recs = []
        for i in np.flatnonzero(s["done_mask"]):
            recs.append(CircuitRecord(
                genome_nodes=s["parent_nodes"][i],
                genome_outs=s["parent_outs"][i],
                metrics=s["metrics"][i],
                power_rel=float(s["power_rel"][i]),
                constraint=grid[i]["constraint"],
                seed=int(grid[i]["seed"]),
                feasible=bool(s["feasible"][i]),
                error_mean=float(s["error_mean"][i]),
                error_std=float(s["error_std"][i]),
                metrics_stderr=s["metrics_stderr"][i],
                certified=bool(s["certified_mask"][i]),
            ))
        return recs

    # -- pareto feeds (mirror SweepResult's methods) ------------------------

    def _masked(self, feasible_only: bool):
        s = self.summary(["metrics", "power_rel", "feasible"])
        mask = s["done_mask"] & (s["feasible"].astype(bool)
                                 if feasible_only else True)
        return s["metrics"][mask], s["power_rel"][mask]

    def correlations(self, feasible_only: bool = True) -> np.ndarray:
        """|Pearson| cross-metric correlations over committed runs (paper
        Fig. 6) — bit-identical to ``SweepResult.correlations``."""
        from repro.core.pareto import metric_correlations
        metrics, _ = self._masked(feasible_only)
        return metric_correlations(metrics)

    def fronts(self, metric_indices: Sequence[int] = (M.MAE, M.ER),
               feasible_only: bool = True) -> dict[int, np.ndarray]:
        """Power-vs-metric Pareto fronts (paper Figs. 7-14 axes) —
        bit-identical to ``SweepResult.fronts``."""
        from repro.core.pareto import sweep_fronts
        metrics, power = self._masked(feasible_only)
        return sweep_fronts(power, metrics, metric_indices)

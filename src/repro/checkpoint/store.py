"""Sharded checkpoint store with async save and elastic resharding.

Layout:  <dir>/step_<N>/
           manifest.json        (tree structure, shapes, dtypes)
           leaf_<i>.npy         (one array per tree leaf)
           _COMPLETE            (commit marker — atomic visibility)

Restore accepts a tree of NamedShardings (possibly for a DIFFERENT mesh than
the one that saved): leaves are device_put with the new sharding, which is
the elastic-rescale path (mesh 16×16 checkpoint → 2×16×16 restore is tested
in tests/test_checkpoint.py).  Writes go through a temp dir + rename and a
commit marker, so a host failure mid-save can never corrupt the latest
checkpoint — restart resumes from the last committed step.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

Tree = Any
_MARKER = "_COMPLETE"


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directories need an O_RDONLY fd;
    works on the POSIX filesystems this repo targets)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_json(path: str, obj: Any) -> None:
    """Write JSON through a temp file + fsync + rename so readers never
    observe a partially-written file (shared by the checkpoint manifests and
    the streaming results layer in ``core.results``).

    The temp name is unique per writer (mkstemp), not a fixed ``path.tmp``:
    multiple pods of a sharded sweep may race to create the same manifest
    with identical bytes, and a shared temp path would let one writer
    truncate the file under another mid-write — last rename wins instead.

    Durability (DESIGN.md §11): the file is fsync'd BEFORE the rename and
    the parent directory after it.  Rename-without-fsync lets a power loss
    reorder the rename ahead of the data blocks — the classic
    empty-but-renamed file — which would break the "presence == committed"
    contract every reader of these files relies on.
    """
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=os.path.basename(path) + ".tmp.")
    try:
        # mkstemp creates 0600; restore the umask-derived mode plain open()
        # would give, so shared-results manifests stay readable cross-user
        umask = os.umask(0)
        os.umask(umask)
        os.fchmod(fd, 0o666 & ~umask)
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=1, default=float)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_path(os.path.dirname(path) or ".")
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def atomic_save_npz(path: str, arrays: dict[str, np.ndarray]) -> None:
    """Atomically commit an ``.npz`` bundle: the file either exists complete
    or not at all, so presence alone is the commit marker (the results-layer
    shards rely on this — no ``_COMPLETE`` sidecar needed per shard).

    Like ``atomic_write_json``, the bundle is fsync'd before the rename and
    the parent directory after it, so a crash cannot surface a zero-byte or
    truncated file under the committed name (DESIGN.md §11); a failure at
    any point removes the temp file and leaves the committed name untouched.
    """
    tmp = path + ".tmp.npz"
    try:
        np.savez(tmp, **arrays)
        _fsync_path(tmp)
        os.replace(tmp, path)
        _fsync_path(os.path.dirname(path) or ".")
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def _leaf_paths(tree: Tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Tree,
                    metadata: dict | None = None) -> str:
    """Atomic synchronous save.  Returns the checkpoint path."""
    leaves, treedef = _leaf_paths(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if False else None,  # proto not stable across jax versions; use repr
        "n_leaves": len(leaves),
        "metadata": metadata or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": str(arr.dtype)})
    atomic_write_json(os.path.join(tmp, "manifest.json"), manifest)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_path(ckpt_dir)
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (single in-flight save)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, ckpt_dir: str, step: int, tree: Tree,
             metadata: dict | None = None):
        self.wait()
        # materialize on host BEFORE returning control (device buffers may be
        # donated by the next step)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self._thread = threading.Thread(
            target=save_checkpoint, args=(ckpt_dir, step, host_tree, metadata),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def committed_steps(ckpt_dir: str) -> list[int]:
    """All committed step numbers, ascending."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _MARKER)):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_metadata(ckpt_dir: str, step: int) -> dict:
    """Read only a checkpoint's metadata (cheap identity/fingerprint check
    before committing to a full ``load_checkpoint`` deserialization)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["metadata"]


def load_checkpoint(ckpt_dir: str, step: int, template: Tree,
                    shardings: Tree | None = None) -> tuple[Tree, dict]:
    """Load into the structure of ``template``; optional resharding.

    ``shardings``: tree of jax.sharding.Sharding (or None leaves) matching
    ``template`` — the elastic path: a checkpoint saved on one mesh restores
    onto any other mesh/topology.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _leaf_paths(template)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, template "
        f"{len(leaves)} — structure changed")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (tmpl, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        assert list(arr.shape) == list(tmpl.shape), (
            f"leaf {i}: ckpt {arr.shape} vs template {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    """Retain the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)

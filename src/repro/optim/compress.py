"""Gradient compression with error feedback (distributed-optimization trick).

Int8 block-quantized gradient exchange: before the data-parallel reduction,
each shard quantizes its gradient block-wise to int8 (absmax scaling) and
keeps the quantization residual in an error-feedback buffer that is added to
the next step's gradient — the standard EF-SGD construction that preserves
convergence.  ``compressed_psum`` is the shard_map collective used by the
launcher when ``--grad-compress`` is set; 4x less ICI traffic on the DP
all-reduce, which EXPERIMENTS.md §Perf quantifies against the collective
roofline term.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any
BLOCK = 256


def quantize_leaf(g: jax.Array):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    x = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return x[:n].reshape(shape)


def compress_with_feedback(grads: Tree, error: Tree):
    """(grads + error) -> (quantized tree {"q","s"} per leaf, new error)."""
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(error)
    qt, err = [], []
    for g, e in zip(g_leaves, e_leaves):
        g = g.astype(jnp.float32) + e
        q, s = quantize_leaf(g)
        deq = dequantize_leaf(q, s, g.shape)
        qt.append({"q": q, "s": s})
        err.append(g - deq)
    return (jax.tree.unflatten(treedef, qt),
            jax.tree.unflatten(treedef, err))


def compressed_psum(grads: Tree, error: Tree, axis_name) -> tuple[Tree, Tree]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Returns (mean gradients fp32, new error buffers).
    """
    n = jax.lax.psum(1, axis_name)
    g_leaves, treedef = jax.tree.flatten(grads)
    e_leaves = treedef.flatten_up_to(error)
    red, err = [], []
    for g, e in zip(g_leaves, e_leaves):
        g = g.astype(jnp.float32) + e
        q, s = quantize_leaf(g)
        deq = dequantize_leaf(q, s, g.shape)
        err.append(g - deq)
        # int8 payloads summed in fp32 after scaling (the wire format is the
        # int8 tensor + per-block scales; psum here models the exchange)
        red.append(jax.lax.psum(deq, axis_name) / n)
    return (jax.tree.unflatten(treedef, red),
            jax.tree.unflatten(treedef, err))


def init_error(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""Optimizers: AdamW, block-wise 8-bit AdamW, Adafactor.

All tree-based pure functions (no optax dependency).  The 8-bit and factored
variants are the distributed-optimization levers that make the paper-table
architectures (kimi-k2 1T) fit the production mesh: moment memory drops from
8 bytes/param fp32 to ~2 bytes (8-bit) or ~0 (factored) — the per-cell
effect is quantified in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.schedule import lr_at

Tree = Any
BLOCK = 256  # 8-bit moment quantization block size


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | adamw8bit | adafactor
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def global_norm(tree: Tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Tree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ----------------------------- 8-bit moments --------------------------------
# Per-row DYNAMIC int8 quantization (bitsandbytes-style dynamic map): values
# are stored as sign(x)·sqrt(|x|/rowmax) so small entries keep relative
# resolution — critical for Adam's v, where a linearly-quantized near-zero
# second moment would zero out and explode the update through 1/sqrt(v).
#
# SHAPE-PRESERVING on purpose: q has the param's exact shape (int8) and the
# scale drops the last axis, so both inherit the param's sharding verbatim
# (opt_state_specs) and the optimizer update stays fully local — a flat
# blocked layout would misalign with the param shards and forced GSPMD into
# full f32 all-reduce + s8 all-gather of every moment per step
# (EXPERIMENTS.md §Perf hillclimb B2).

def _q8(x: jax.Array):
    if x.ndim == 0:
        x = x.reshape(1)
        q, s = _q8(x)
        return q.reshape(()), s.reshape(())
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-12)
    norm = x / scale                               # in [-1, 1]
    q = jnp.clip(jnp.round(127.0 * jnp.sign(norm) *
                           jnp.sqrt(jnp.abs(norm))), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.float32)


def _dq8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    qf = q.astype(jnp.float32) / 127.0
    if q.ndim == 0:
        return jnp.sign(qf) * qf * qf * scale
    return jnp.sign(qf) * qf * qf * scale[..., None]


# ----------------------------- state init -----------------------------------

def init_opt_state(params: Tree, cfg: OptConfig) -> Tree:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.name == "adamw":
        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params)}
    if cfg.name == "adamw8bit":
        def q0(p):
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return {"m": jax.tree.map(q0, params),
                "v": jax.tree.map(q0, params)}
    if cfg.name == "adafactor":
        def fac(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": jax.tree.map(fac, params)}
    raise ValueError(cfg.name)


# ----------------------------- updates --------------------------------------

def _adam_update(g, m, v, step, cfg: OptConfig):
    m = cfg.beta1 * m + (1 - cfg.beta1) * g
    v = cfg.beta2 * v + (1 - cfg.beta2) * g * g
    mh = m / (1 - cfg.beta1 ** (step + 1))
    vh = v / (1 - cfg.beta2 ** (step + 1))
    upd = mh / (jnp.sqrt(vh) + cfg.eps)
    return upd, m, v


def apply_gradients(params: Tree, grads: Tree, state: Tree, step: jax.Array,
                    cfg: OptConfig) -> tuple[Tree, Tree]:
    """One optimizer step.  Returns (new params, new state)."""
    grads, _ = clip_by_global_norm(grads, cfg.clip_norm)
    lr = lr_at(step, cfg)

    if cfg.name == "adamw":
        def upd(p, g, m, v):
            u, m2, v2 = _adam_update(g, m, v, step, cfg)
            p2 = (p.astype(jnp.float32)
                  - lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
            return p2.astype(p.dtype), m2, v2
        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        p2 = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        m2 = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        v2 = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return p2, {"m": m2, "v": v2}

    if cfg.name == "adamw8bit":
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        new_p, new_m, new_v = [], [], []
        for p, g, mq, vq in zip(flat_p, flat_g, flat_m, flat_v):
            m = _dq8(mq["q"], mq["s"], p.shape)
            v = _dq8(vq["q"], vq["s"], p.shape)
            u, m2, v2 = _adam_update(g, m, v, step, cfg)
            p2 = (p.astype(jnp.float32)
                  - lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
            q_m, s_m = _q8(m2)
            q_v, s_v = _q8(v2)
            new_p.append(p2.astype(p.dtype))
            new_m.append({"q": q_m, "s": s_m})
            new_v.append({"q": q_v, "s": s_v})
        return (jax.tree.unflatten(treedef, new_p),
                {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v)})

    if cfg.name == "adafactor":
        def upd(p, g, fac):
            g2 = g * g + 1e-30
            if p.ndim >= 2:
                row = cfg.beta2 * fac["row"] + (1 - cfg.beta2) * g2.mean(-1)
                col = cfg.beta2 * fac["col"] + (1 - cfg.beta2) * g2.mean(-2)
                vhat = (row[..., None] * col[..., None, :]
                        / jnp.maximum(row.mean(-1, keepdims=True)[..., None],
                                      1e-30))
                new_fac = {"row": row, "col": col}
            else:
                v = cfg.beta2 * fac["v"] + (1 - cfg.beta2) * g2
                vhat, new_fac = v, {"v": v}
            u = g / jnp.maximum(jnp.sqrt(vhat), cfg.eps)
            # update clipping (adafactor RMS rule)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            p2 = (p.astype(jnp.float32)
                  - lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
            return p2.astype(p.dtype), new_fac
        out = jax.tree.map(upd, params, grads, state["fac"],
                           is_leaf=lambda t: isinstance(t, dict) and
                           ("row" in t or "v" in t))
        p2 = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        f2 = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return p2, {"fac": f2}

    raise ValueError(cfg.name)


def opt_state_specs(param_specs: Tree, cfg: OptConfig) -> Tree:
    """Sharding specs for optimizer state mirroring the param specs."""
    as_tuple = lambda s: tuple(s)
    if cfg.name == "adamw":
        return {"m": param_specs, "v": param_specs}
    if cfg.name == "adamw8bit":
        # q is shape-preserving -> the param's spec; the per-row scale drops
        # the last axis
        from repro.parallel import ctx
        q = ctx.map_specs(
            lambda s: {"q": tuple(s),
                       "s": tuple(s)[:-1] if len(s) > 0 else ()},
            param_specs)
        return {"m": q, "v": q}
    if cfg.name == "adafactor":
        def fac(s):
            s = tuple(s)
            if len(s) >= 2:
                return {"row": s[:-1], "col": s[:-2] + s[-1:]}
            return {"v": s}
        return {"fac": jax.tree.map(fac, param_specs,
                                    is_leaf=lambda s: isinstance(s, tuple))}
    raise ValueError(cfg.name)

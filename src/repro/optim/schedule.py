"""Learning-rate schedules (warmup + cosine decay)."""
from __future__ import annotations

import jax.numpy as jnp


def lr_at(step, cfg) -> jnp.ndarray:
    """Linear warmup to cfg.lr, then cosine decay to min_lr_ratio*lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1 - floor) * cos)

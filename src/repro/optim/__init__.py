from repro.optim.optimizer import (OptConfig, init_opt_state, apply_gradients,
                                   global_norm, opt_state_specs)
from repro.optim.schedule import lr_at

"""Deterministic synthetic data pipeline + document packing.

The corpus is a seeded Zipf-ish token stream generated per (step, position)
with a counter-based hash — fully deterministic, identical across restarts
and host counts (each host materializes only its batch slice), which is what
the fault-tolerance tests rely on: resume-from-checkpoint replays the exact
batch sequence.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    n_codebooks: int = 0      # audio: tokens get a trailing codebook dim
    zipf_alpha: float = 1.1


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """Counter-based integer hash (xorshift-mult mix), vectorized."""
    x = x.astype(np.uint64)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xFF51AFD7ED558CCD)
    x ^= x >> np.uint64(33)
    x *= np.uint64(0xC4CEB9FE1A85EC53)
    x ^= x >> np.uint64(33)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _zipf_map(u: np.ndarray, vocab: int, alpha: float) -> np.ndarray:
    """Map uniform u32 to a Zipf-ish (log-uniform) rank over [0, vocab).

    P(id = r) ∝ 1/(r+1): inverse CDF id = floor(V^f) - 1 — token frequency
    decays like natural text, which gives the LM a learnable unigram prior.
    """
    f = (u.astype(np.float64) + 1.0) / 2**32
    r = np.power(float(vocab), f)          # in (1, vocab]
    return np.minimum(r.astype(np.int64) - 1, vocab - 1).astype(np.int32)


def synth_batch(cfg: DataConfig, step: int,
                host_slice: slice | None = None) -> dict:
    """Batch for ``step``: {'tokens': (B, S[, C]), 'targets': same}."""
    B, S = cfg.global_batch, cfg.seq_len
    rows = np.arange(B)[host_slice] if host_slice else np.arange(B)
    C = max(1, cfg.n_codebooks)
    pos = (np.uint64(cfg.seed) << np.uint64(48)) \
        + (np.uint64(step) << np.uint64(28))
    idx = (pos + (rows[:, None, None].astype(np.uint64) << np.uint64(16))
           + np.arange(S, dtype=np.uint64)[None, :, None] * np.uint64(C)
           + np.arange(C, dtype=np.uint64)[None, None, :])
    toks = _zipf_map(_hash_u32(idx), cfg.vocab, cfg.zipf_alpha)
    if cfg.n_codebooks == 0:
        toks = toks[..., 0]
    # next-token targets within the synthetic stream
    tgt = np.roll(toks, -1, axis=1)
    return {"tokens": toks, "targets": tgt}


def batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synth_batch(cfg, step)
        step += 1


class PrefetchLoader:
    """Background-thread prefetch over ``batches`` (depth-bounded queue).

    ``close()`` stops the worker; a closed loader drains whatever was already
    queued and then raises ``StopIteration`` — ``__next__`` must never block
    forever on a queue nobody refills (the consumer polls with a timeout so a
    concurrent ``close()`` is also observed, not just one issued before).
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(synth_batch(self.cfg, step), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        while True:
            try:
                return self._q.get(timeout=0.1)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def pack_documents(docs: list[list[int]], seq_len: int,
                   eos: int) -> np.ndarray:
    """Pack variable-length documents into (N, seq_len) rows with EOS
    separators; truncates nothing, splits long docs (property-tested)."""
    flat: list[int] = []
    for d in docs:
        flat.extend(d)
        flat.append(eos)
    n = max(1, (len(flat) + seq_len - 1) // seq_len)
    out = np.full((n, seq_len), eos, dtype=np.int32)
    arr = np.asarray(flat[: n * seq_len], dtype=np.int32)
    out.reshape(-1)[: arr.size] = arr
    return out

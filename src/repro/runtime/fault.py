"""Fault-tolerance runtime: heartbeats, straggler detection, retry, elastic.

This container has one process, so these are the *mechanisms* (unit-tested
with fake clocks) that `launch/train.py` wires together; on a real cluster
the same objects run per-host with the coordination service providing the
failure signal.  Policies implemented:

  * HeartbeatMonitor — per-host liveness with a deadline; dead hosts trigger
    the restore-from-checkpoint path (train loop restarts from the last
    committed step, data pipeline replays deterministically).
  * StragglerDetector — EWMA step-time z-score; flags persistent outliers so
    the scheduler can evict/replace them (mitigation = checkpoint + elastic
    restart on the shrunken/replaced mesh, see checkpoint.store resharding).
  * retry — transient-error wrapper with exponential backoff (I/O, preemption
    races).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class HeartbeatMonitor:
    deadline_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self._last: dict[str, float] = {}

    def beat(self, host: str) -> None:
        self._last[host] = self.clock()

    def dead_hosts(self) -> list[str]:
        now = self.clock()
        return [h for h, t in self._last.items()
                if now - t > self.deadline_s]

    def alive(self, host: str) -> bool:
        return host not in self.dead_hosts() and host in self._last


@dataclasses.dataclass
class StragglerDetector:
    """Flags hosts whose step time is persistently > threshold× the fleet
    EWMA.  ``observe`` returns the current straggler set."""
    alpha: float = 0.2           # EWMA smoothing
    threshold: float = 1.8       # x fleet mean
    patience: int = 3            # consecutive violations before flagging

    def __post_init__(self):
        self._ewma: dict[str, float] = {}
        self._strikes: dict[str, int] = {}

    def observe(self, host: str, step_time_s: float) -> list[str]:
        prev = self._ewma.get(host, step_time_s)
        self._ewma[host] = (1 - self.alpha) * prev + self.alpha * step_time_s
        fleet = sorted(self._ewma.values())
        median = fleet[len(fleet) // 2]
        if self._ewma[host] > self.threshold * median and len(fleet) > 1:
            self._strikes[host] = self._strikes.get(host, 0) + 1
        else:
            self._strikes[host] = 0
        return [h for h, s in self._strikes.items() if s >= self.patience]


def retry(fn: Callable, retries: int = 3, backoff_s: float = 0.1,
          exceptions: tuple = (OSError, IOError),
          sleep: Callable[[float], None] = time.sleep):
    """Run ``fn`` with exponential-backoff retries on transient errors."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except exceptions as e:  # noqa: PERF203
            last = e
            if attempt == retries:
                raise
            sleep(backoff_s * (2 ** attempt))
    raise last  # unreachable


@dataclasses.dataclass
class TrainGuard:
    """Composes the mechanisms into the policy the train loop consumes."""
    monitor: HeartbeatMonitor
    detector: StragglerDetector
    on_failure: Callable[[list[str]], None] = lambda hosts: None

    def step(self, host: str, step_time_s: float) -> dict:
        self.monitor.beat(host)
        stragglers = self.detector.observe(host, step_time_s)
        dead = self.monitor.dead_hosts()
        if dead:
            self.on_failure(dead)
        return {"dead": dead, "stragglers": stragglers}

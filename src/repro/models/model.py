"""Composable decoder-only LM covering all ten assigned architectures.

A model is a repeating *period* of LayerSpecs (configs/base.py): dense GQA
transformers are a 1-layer period; jamba is an 8-layer period (1 attn : 7
mamba, MoE on odd layers); llama-3.2-vision a 5-layer period (1 cross-attn +
4 self-attn); mamba2 a 1-layer ssm period without FFN.  Parameters for the
period are stacked over ``n_periods`` and the stack is traversed with
``lax.scan`` — HLO size is independent of depth, which is what keeps the
512-device dry-run compiles tractable (DESIGN.md §5).

Three entry points per the shape cells:
    forward_train  — full-sequence logits (+ MoE aux loss)
    prefill        — logits + populated caches
    decode_step    — one token against caches (KV / SSM / cross)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import (embed_specs, embed_tokens, init_embed,
                                 init_mlp, mlp, mlp_specs, rms_norm)
from repro.parallel import ctx

Tree = Any


# ------------------------------- init ---------------------------------------

def _init_period(key, cfg: ModelConfig) -> Tree:
    p = {}
    for i, spec in enumerate(cfg.period):
        k_mix, k_ffn = jax.random.split(jax.random.fold_in(key, i))
        if spec.kind == "ssm":
            mix = SSM.init_ssm(k_mix, cfg)
        else:
            mix = A.init_attention(k_mix, cfg, cross=spec.cross_attn)
        lp = {"mixer": mix}
        if spec.has_ffn:
            lp["ffn"] = (MOE.init_moe(k_ffn, cfg) if spec.moe
                         else init_mlp(k_ffn, cfg))
        p[f"layer{i}"] = lp
    return p


def init_params(key, cfg: ModelConfig) -> Tree:
    k_e, k_l, k_h = jax.random.split(key, 3)
    period_keys = jax.random.split(k_l, cfg.n_periods)
    layers = jax.vmap(lambda k: _init_period(k, cfg))(period_keys)
    params = {
        "embed": init_embed(k_e, cfg),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype()),
    }
    if cfg.frontend == "audio":
        params["lm_head"] = (jax.random.normal(
            k_h, (cfg.n_codebooks, cfg.d_model, cfg.vocab), jnp.float32)
            * 0.02).astype(cfg.pdtype())
    elif not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            k_h, (cfg.d_model, cfg.vocab), jnp.float32) * 0.02
            ).astype(cfg.pdtype())
    return params


def _period_specs(cfg: ModelConfig) -> Tree:
    p = {}
    for i, spec in enumerate(cfg.period):
        if spec.kind == "ssm":
            mix = SSM.ssm_specs(cfg)
        else:
            mix = A.attention_specs(cfg, cross=spec.cross_attn)
        lp = {"mixer": mix}
        if spec.has_ffn:
            lp["ffn"] = MOE.moe_specs(cfg) if spec.moe else mlp_specs(cfg)
        p[f"layer{i}"] = lp
    return p


def param_specs(cfg: ModelConfig) -> Tree:
    """Tree of LOGICAL sharding tuples matching init_params exactly."""
    layer = ctx.map_specs(lambda s: (None,) + tuple(s), _period_specs(cfg))
    specs = {
        "embed": embed_specs(cfg),
        "layers": layer,
        "final_norm": (None,),
    }
    if cfg.frontend == "audio":
        specs["lm_head"] = (None, None, "tp")
    elif not cfg.tie_embeddings:
        specs["lm_head"] = (None, "tp")
    return specs


# ------------------------------- forward ------------------------------------

def _use_ep(cfg: ModelConfig) -> bool:
    mesh = ctx.get_mesh()
    return (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1
            and cfg.moe.n_experts % mesh.shape["model"] == 0)


def _apply_ffn(spec: LayerSpec, lp: Tree, x, cfg: ModelConfig,
               full_capacity: bool = False):
    if not spec.has_ffn:
        return x, 0.0
    if spec.moe:
        if _use_ep(cfg):
            return MOE.moe_ffn_ep(lp["ffn"], x, cfg, ctx.get_mesh(),
                                  full_capacity=full_capacity)
        return MOE.moe_ffn(lp["ffn"], x, cfg, full_capacity=full_capacity)
    return mlp(lp["ffn"], x, cfg), 0.0


def _apply_period(period_params: Tree, x, cfg: ModelConfig,
                  image_embeds=None):
    """One period of layers (train/prefill, no cache)."""
    from jax.ad_checkpoint import checkpoint_name
    aux = 0.0
    for i, spec in enumerate(cfg.period):
        lp = period_params[f"layer{i}"]
        x = ctx.shard(x, "dp", None, None)
        if spec.kind == "ssm":
            x, _ = SSM.ssm_forward(lp["mixer"], x, cfg)
        elif spec.cross_attn:
            x, _ = A.cross_attention(lp["mixer"], x, image_embeds, cfg)
        else:
            x, _ = A.self_attention(lp["mixer"], x, cfg)
        x = checkpoint_name(x, "mixer_out")
        x, a = _apply_ffn(spec, lp, x, cfg)
        x = checkpoint_name(x, "ffn_out")
        aux = aux + a
    return x, aux


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_policy == "block_outputs":
        return jax.checkpoint_policies.save_only_these_names(
            "mixer_out", "ffn_out")
    return jax.checkpoint_policies.nothing_saveable


def backbone(params: Tree, x: jax.Array, cfg: ModelConfig,
             image_embeds=None) -> tuple[jax.Array, jax.Array]:
    """Embedded inputs -> final hidden states (scan over periods)."""
    period_fn = functools.partial(_apply_period, cfg=cfg,
                                  image_embeds=image_embeds)
    if cfg.remat:
        period_fn = jax.checkpoint(period_fn, policy=_remat_policy(cfg))
    if cfg.scan_layers and cfg.n_periods > 1:
        def body(carry, period_params):
            x, aux = carry
            x, a = period_fn(period_params, x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    else:
        aux = 0.0
        for p_idx in range(cfg.n_periods):
            pp = jax.tree.map(lambda l: l[p_idx], params["layers"])
            x, a = period_fn(pp, x)
            aux = aux + a
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def logits_from_hidden(params: Tree, x: jax.Array,
                       cfg: ModelConfig) -> jax.Array:
    if cfg.frontend == "audio":
        w = params["lm_head"].astype(x.dtype)        # (C, D, V)
        return jnp.einsum("bsd,cdv->bscv", x, w)
    if cfg.tie_embeddings:
        w = params["embed"]["tokens"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    return x @ w


def forward_train(params: Tree, tokens: jax.Array, cfg: ModelConfig,
                  image_embeds=None):
    """tokens -> (logits, moe aux loss)."""
    x = embed_tokens(params["embed"], tokens, cfg)
    x = ctx.shard(x, "dp", None, None)
    x, aux = backbone(params, x, cfg, image_embeds)
    return logits_from_hidden(params, x, cfg), aux


def lm_loss(params: Tree, tokens, targets, cfg: ModelConfig,
            image_embeds=None):
    """Mean cross-entropy (+ MoE aux).  Optional vocab-chunked CE."""
    x = embed_tokens(params["embed"], tokens, cfg)
    x = ctx.shard(x, "dp", None, None)
    x, aux = backbone(params, x, cfg, image_embeds)
    if cfg.loss_vocab_chunk and cfg.frontend != "audio":
        ce = _chunked_ce(params, x, targets, cfg)
    else:
        logits = logits_from_hidden(params, x, cfg).astype(jnp.float32)
        if cfg.frontend == "audio":
            lse = jax.nn.logsumexp(logits, axis=-1)            # (B,S,C)
            tgt = jnp.take_along_axis(
                logits, targets[..., None], axis=-1)[..., 0]
            ce = (lse - tgt).mean()
        else:
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(
                logits, targets[..., None], axis=-1)[..., 0]
            ce = (lse - tgt).mean()
    return ce + 0.01 * aux


def _chunked_ce(params, x, targets, cfg: ModelConfig):
    """Sequence-chunked CE that never materializes (B,S,V) logits.

    Memory-roofline optimization (EXPERIMENTS.md §Perf): peak goes from
    O(B·S·V) to O(B·chunk·V).
    """
    B, S, D = x.shape
    C = cfg.loss_vocab_chunk
    n = max(1, S // C)
    xs = x[:, :n * C].reshape(B, n, C, D).transpose(1, 0, 2, 3)
    ts = targets[:, :n * C].reshape(B, n, C).transpose(1, 0, 2)

    def chunk_loss(carry, xt):
        xc, tc = xt
        logits = logits_from_hidden(params, xc, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return carry + (lse - tgt).sum(), None

    total, _ = jax.lax.scan(chunk_loss, 0.0, (xs, ts))
    return total / (B * n * C)


# ------------------------------- caches -------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Tree:
    """Decode caches stacked over periods (leading dim n_periods)."""
    dtype = dtype or cfg.adtype()
    hd = cfg.hd

    def one_period():
        c = {}
        for i, spec in enumerate(cfg.period):
            if spec.kind == "ssm":
                c[f"layer{i}"] = SSM.init_ssm_state(cfg, batch, dtype)
            elif spec.cross_attn:
                c[f"layer{i}"] = {
                    "k": jnp.zeros((batch, cfg.n_img_tokens,
                                    cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((batch, cfg.n_img_tokens,
                                    cfg.n_kv_heads, hd), dtype)}
            else:
                c[f"layer{i}"] = {
                    "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd),
                                   dtype),
                    "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd),
                                   dtype)}
        return c

    one = one_period()
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l[None], (cfg.n_periods,) + l.shape), one)


def cache_specs(cfg: ModelConfig, batch: int) -> Tree:
    """Logical sharding for caches: batch over dp when divisible, else the
    sequence axis (long_500k: flash-decoding split-KV, DESIGN.md §5)."""
    seq_shard = batch < ctx.axis_size("dp")
    tp = ctx.axis_size("tp")
    # shard kv-heads over tp only when divisible; else shard head_dim
    if cfg.n_kv_heads % max(tp, 1) == 0:
        kv_spec = ("dp", None, "tp", None)
    elif cfg.hd % max(tp, 1) == 0:
        kv_spec = ("dp", None, None, "tp")
    else:
        kv_spec = ("dp", None, None, None)
    c = {}
    for i, spec in enumerate(cfg.period):
        if spec.kind == "ssm":
            c[f"layer{i}"] = SSM.SSMState(
                conv_x=(None, None, "tp"),
                conv_bc=(None, None, None),
                ssm=(None, "tp", None, None))
        elif spec.cross_attn or not seq_shard:
            c[f"layer{i}"] = {"k": kv_spec, "v": kv_spec}
        else:  # flash-decoding: sequence axis of the cache over "sp";
            # heads replicated to match the split-KV shard_map exactly
            c[f"layer{i}"] = {"k": (None, "sp", None, None),
                              "v": (None, "sp", None, None)}
    return c


# ------------------------------- decode -------------------------------------

def decode_step(params: Tree, cache: Tree, tokens: jax.Array,
                pos: jax.Array, cfg: ModelConfig,
                seq_shard: bool = False):
    """One decode step.  tokens: (B, 1) (or (B, 1, C) audio); pos: (B,).

    seq_shard=True runs attention-cache reads under shard_map with split-KV
    LSE merging (long_500k path).
    """
    x = embed_tokens(params["embed"], tokens, cfg)
    cspecs = cache_specs(cfg, tokens.shape[0])

    def period_fn(x, period_params, period_cache):
        new_cache = {}
        for i, spec in enumerate(cfg.period):
            lp = period_params[f"layer{i}"]
            lc = period_cache[f"layer{i}"]
            if spec.kind == "ssm":
                x, st = SSM.ssm_decode_step(lp["mixer"], x, lc, cfg)
                new_cache[f"layer{i}"] = st
            elif spec.cross_attn:
                x, _ = A.cross_attention(lp["mixer"], x, None, cfg,
                                         kv_cache=(lc["k"], lc["v"]))
                new_cache[f"layer{i}"] = lc
            else:
                if seq_shard:
                    x, kc, vc = _decode_attn_seqshard(lp["mixer"], x, lc,
                                                      pos, cfg)
                else:
                    x, kc, vc = A.decode_self_attention(
                        lp["mixer"], x, lc["k"], lc["v"], pos, cfg,
                        kv_spec=cspecs[f"layer{i}"]["k"])
                new_cache[f"layer{i}"] = {"k": kc, "v": vc}
            x, _ = _apply_ffn(spec, lp, x, cfg, full_capacity=True)
        return x, new_cache

    if cfg.scan_layers and cfg.n_periods > 1:
        def body(x, pc):
            period_params, period_cache = pc
            x, nc = period_fn(x, period_params, period_cache)
            return x, nc
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        ncs = []
        for p_idx in range(cfg.n_periods):
            pp = jax.tree.map(lambda l: l[p_idx], params["layers"])
            pc = jax.tree.map(lambda l: l[p_idx], cache)
            x, nc = period_fn(x, pp, pc)
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, x, cfg), new_cache


def _decode_attn_seqshard(lp, x, lc, pos, cfg: ModelConfig):
    """shard_map wrapper: cache sequence axis sharded over dp ("sp")."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = ctx.get_mesh()
    if mesh is None:
        return A.decode_self_attention(lp, x, lc["k"], lc["v"], pos, cfg)
    sp = ctx.resolve_axis("sp")

    def local(lp_l, x_l, k_l, v_l, pos_l):
        return A.decode_self_attention(lp_l, x_l, k_l, v_l, pos_l, cfg,
                                       axis_name=sp)

    # all shards see replicated x/params; cache is split on sequence
    pspec = jax.tree.map(lambda _: P(), lp)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pspec, P(), P(None, sp, None, None),
                  P(None, sp, None, None), P()),
        out_specs=(P(), P(None, sp, None, None), P(None, sp, None, None)),
        check_rep=False)
    return fn(lp, x, lc["k"], lc["v"], pos)


# ------------------------------- prefill ------------------------------------

def prefill(params: Tree, tokens: jax.Array, cfg: ModelConfig,
            max_len: int | None = None, image_embeds=None):
    """Process a prompt, returning last-position logits and filled caches."""
    B = tokens.shape[0]
    S = tokens.shape[1]
    max_len = max_len or S
    x = embed_tokens(params["embed"], tokens, cfg)

    def period_fn(x, period_params):
        new_cache = {}
        for i, spec in enumerate(cfg.period):
            lp = period_params[f"layer{i}"]
            if spec.kind == "ssm":
                x, st = SSM.ssm_forward(lp["mixer"], x, cfg,
                                        return_state=True)
                new_cache[f"layer{i}"] = st
            elif spec.cross_attn:
                x, (k, v) = A.cross_attention(lp["mixer"], x, image_embeds,
                                              cfg)
                new_cache[f"layer{i}"] = {"k": k, "v": v}
            else:
                x, (k, v) = A.self_attention(lp["mixer"], x, cfg)
                pad = max_len - S
                if pad > 0:
                    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                new_cache[f"layer{i}"] = {"k": k, "v": v}
            x, _ = _apply_ffn(spec, lp, x, cfg)
        return x, new_cache

    if cfg.scan_layers and cfg.n_periods > 1:
        x, cache = jax.lax.scan(
            lambda c, pp: period_fn(c, pp), x, params["layers"])
    else:
        caches = []
        for p_idx in range(cfg.n_periods):
            pp = jax.tree.map(lambda l: l[p_idx], params["layers"])
            x, nc = period_fn(x, pp)
            caches.append(nc)
        cache = jax.tree.map(lambda *ls: jnp.stack(ls), *caches)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, x[:, -1:], cfg)
    return logits, cache

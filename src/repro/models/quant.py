"""int8 quantization + evolved-approximate-multiplier matmul emulation.

This is the deployment bridge for the paper's circuits (DESIGN.md §4):
``set_multiplier_lut`` installs a 256×256 product table (from
``core.library.multiplier_lut`` of an evolved 8×8 multiplier) and
``approx_matmul`` then computes every projection as

    y = scale_x · scale_w · Σ_k LUT[q(x)[m,k], q(w)[k,n]]

i.e. the *exact* arithmetic a chip built from the evolved circuit would
perform on uint8-quantized operands (asymmetric per-tensor quantization so
operands are non-negative — matching the unsigned multipliers the paper
evolves; the zero-point cross terms are corrected exactly with row/col sums).

With no LUT installed the emulation reduces to exact int8 matmul (tested
equal to float matmul up to quantization error).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

_LUT: jax.Array | None = None  # (256, 256) int32, LUT[a, b] ≈ a*b

#: rows-per-chunk bound of the reference gather: the oracle materializes an
#: (m, K, N) int32 tensor per chunk, so cap m such that m*K*N stays around
#: 2^24 elements (~64 MB) regardless of batch/sequence size
_REF_CHUNK_ELEMS = 1 << 24


def _lut_backend() -> str:
    """Which LUT-matmul implementation ``approx_matmul`` dispatches to:
    the Pallas kernel (``kernels.ops.lut_matmul``) or the jnp gather oracle
    (``kernels.ref.lut_matmul_ref``).  ``REPRO_LUT_BACKEND`` forces one
    ("kernel" / "ref"); "auto" (default) picks the kernel on TPU — where it
    runs compiled — and the oracle elsewhere (interpret-mode Pallas on CPU
    is far slower than the gather, with bit-identical results either way;
    tests/test_lut_matmul.py holds the two equal)."""
    mode = os.environ.get("REPRO_LUT_BACKEND", "auto")
    if mode not in ("auto", "kernel", "ref"):
        raise ValueError(f"REPRO_LUT_BACKEND must be auto|kernel|ref, "
                         f"got {mode!r}")
    if mode == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "ref"
    return mode


def set_multiplier_lut(lut: np.ndarray | None) -> None:
    global _LUT
    _LUT = None if lut is None else jnp.asarray(lut, jnp.int32)


def get_multiplier_lut() -> jax.Array:
    if _LUT is None:
        a = jnp.arange(256, dtype=jnp.int32)
        return a[:, None] * a[None, :]
    return _LUT


def quantize_u8(x: jax.Array, axis=None):
    """Asymmetric uint8: returns (q, scale, zero) with x ≈ scale*(q - zero)."""
    xf = x.astype(jnp.float32)
    lo = xf.min() if axis is None else xf.min(axis, keepdims=True)
    hi = xf.max() if axis is None else xf.max(axis, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / 255.0
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(xf / scale + zero), 0, 255).astype(jnp.int32)
    return q, scale, zero


def approx_matmul(x: jax.Array, w: jax.Array,
                  lut: jax.Array | None = None) -> jax.Array:
    """x: (..., K) fp; w: (K, N) fp -> (..., N) fp via LUT arithmetic."""
    lut = get_multiplier_lut() if lut is None else lut
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    qx, sx, zx = quantize_u8(x2)
    qw, sw, zw = quantize_u8(w)

    M, N = x2.shape[0], w.shape[1]
    if _lut_backend() == "kernel":
        from repro.kernels import ops as kops
        acc = kops.lut_matmul(qx, qw, lut)
    else:
        from repro.kernels import ref as kref
        # chunk the M dim so the oracle's (m, K, N) gather stays bounded;
        # M is static under jit, so the loop unrolls to a fixed concat
        rows = max(1, _REF_CHUNK_ELEMS // max(1, K * N))
        if M <= rows:
            acc = kref.lut_matmul_ref(qx, qw, lut)
        else:
            acc = jnp.concatenate(
                [kref.lut_matmul_ref(qx[m:m + rows], qw, lut)
                 for m in range(0, M, rows)], axis=0)
    acc = acc.astype(jnp.float32)
    # exact zero-point correction: Σ(qx-zx)(qw-zw) = Σqxqw - zwΣqx - zxΣqw
    # + K·zx·zw — the Σqxqw term uses the (approximate) LUT, the correction
    # terms are exact integer sums (they would be adders on silicon).
    row = qx.sum(-1, keepdims=True).astype(jnp.float32)       # (M,1)
    col = qw.sum(0, keepdims=True).astype(jnp.float32)        # (1,N)
    corr = acc - zw * row - zx * col + K * zx * zw
    y = sx * sw * corr
    return y.reshape(*lead, N).astype(x.dtype)


def quant_error(x: jax.Array, w: jax.Array,
                lut: jax.Array | None = None) -> float:
    """Relative Frobenius error of the emulated matmul vs exact fp."""
    y_ref = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    y = approx_matmul(x, w, lut).astype(jnp.float32)
    return float(jnp.linalg.norm(y - y_ref) /
                 jnp.maximum(jnp.linalg.norm(y_ref), 1e-9))

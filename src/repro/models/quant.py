"""int8 quantization + evolved-approximate-multiplier matmul emulation.

This is the deployment bridge for the paper's circuits (DESIGN.md §4):
``set_multiplier_lut`` installs a 256×256 product table (from
``core.library.multiplier_lut`` of an evolved 8×8 multiplier) and
``approx_matmul`` then computes every projection as

    y = scale_x · scale_w · Σ_k LUT[q(x)[m,k], q(w)[k,n]]

i.e. the *exact* arithmetic a chip built from the evolved circuit would
perform on uint8-quantized operands (asymmetric per-tensor quantization so
operands are non-negative — matching the unsigned multipliers the paper
evolves; the zero-point cross terms are corrected exactly with row/col sums).

With no LUT installed the emulation reduces to exact int8 matmul (tested
equal to float matmul up to quantization error).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_LUT: jax.Array | None = None  # (256, 256) int32, LUT[a, b] ≈ a*b


def set_multiplier_lut(lut: np.ndarray | None) -> None:
    global _LUT
    _LUT = None if lut is None else jnp.asarray(lut, jnp.int32)


def get_multiplier_lut() -> jax.Array:
    if _LUT is None:
        a = jnp.arange(256, dtype=jnp.int32)
        return a[:, None] * a[None, :]
    return _LUT


def quantize_u8(x: jax.Array, axis=None):
    """Asymmetric uint8: returns (q, scale, zero) with x ≈ scale*(q - zero)."""
    xf = x.astype(jnp.float32)
    lo = xf.min() if axis is None else xf.min(axis, keepdims=True)
    hi = xf.max() if axis is None else xf.max(axis, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-8) / 255.0
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(xf / scale + zero), 0, 255).astype(jnp.int32)
    return q, scale, zero


def approx_matmul(x: jax.Array, w: jax.Array,
                  lut: jax.Array | None = None) -> jax.Array:
    """x: (..., K) fp; w: (K, N) fp -> (..., N) fp via LUT arithmetic."""
    lut = get_multiplier_lut() if lut is None else lut
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    qx, sx, zx = quantize_u8(x2)
    qw, sw, zw = quantize_u8(w)

    from repro.kernels import ref as kref
    M, N = x2.shape[0], w.shape[1]
    # chunk the M dim so the (M, K, N) gather in the oracle stays bounded;
    # on TPU this dispatches to kernels.ops.lut_matmul instead.
    if jax.default_backend() == "tpu":
        from repro.kernels import ops as kops
        acc = kops.lut_matmul(qx, qw, lut)
    else:
        acc = kref.lut_matmul_ref(qx, qw, lut)
    acc = acc.astype(jnp.float32)
    # exact zero-point correction: Σ(qx-zx)(qw-zw) = Σqxqw - zwΣqx - zxΣqw
    # + K·zx·zw — the Σqxqw term uses the (approximate) LUT, the correction
    # terms are exact integer sums (they would be adders on silicon).
    row = qx.sum(-1, keepdims=True).astype(jnp.float32)       # (M,1)
    col = qw.sum(0, keepdims=True).astype(jnp.float32)        # (1,N)
    corr = acc - zw * row - zx * col + K * zx * zw
    y = sx * sw * corr
    return y.reshape(*lead, N).astype(x.dtype)


def quant_error(x: jax.Array, w: jax.Array,
                lut: jax.Array | None = None) -> float:
    """Relative Frobenius error of the emulated matmul vs exact fp."""
    y_ref = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    y = approx_matmul(x, w, lut).astype(jnp.float32)
    return float(jnp.linalg.norm(y - y_ref) /
                 jnp.maximum(jnp.linalg.norm(y_ref), 1e-9))

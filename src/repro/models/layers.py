"""Shared neural-net building blocks (pure-function params-as-pytrees style).

Every module provides ``init_*`` (param tree), ``*_specs`` (matching tree of
LOGICAL sharding axes, resolved to mesh axes by parallel/sharding.py) and an
apply function.  Logical axes used throughout:

    "fsdp"   parameter shards over the (pod, data) axes (ZeRO-style)
    "tp"     tensor-parallel shard over the model axis
    None     replicated
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Tree = Any


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def matmul(x: jax.Array, w: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Projection matmul; routes through the approximate-multiplier emulation
    when ``cfg.approx_matmul`` (models/quant.py — evolved-circuit LUT)."""
    if cfg.approx_matmul:
        from repro.models import quant
        return quant.approx_matmul(x, w)
    return x @ w


# ----------------------------- rotary embeddings ---------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """(sin, cos) with shape positions.shape + (head_dim/2,)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., S, H, D); sin/cos: (..., S, D/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(dt)


# ----------------------------- embeddings ----------------------------------

def init_embed(key, cfg: ModelConfig) -> Tree:
    dt = cfg.pdtype()
    if cfg.frontend == "audio":
        tok = (jax.random.normal(key, (cfg.n_codebooks, cfg.vocab,
                                       cfg.d_model), jnp.float32)
               * 0.02).astype(dt)
    else:
        tok = (jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)
               * 0.02).astype(dt)
    return {"tokens": tok}


def embed_specs(cfg: ModelConfig) -> Tree:
    """Vocab-shard the table over "tp" when divisible; else shard d_model
    (mamba2's GPT-NeoX vocab 50280 is not 16-divisible)."""
    from repro.parallel import ctx
    tp = max(1, ctx.axis_size("tp"))
    dp = max(1, ctx.axis_size("dp"))
    v_tp = "tp" if cfg.vocab % tp == 0 else None
    v_fs = "fsdp" if cfg.vocab % dp == 0 else None
    # NOTE (§Perf hillclimb B4, REVERTED): fsdp-sharding the d axis here
    # looked free for the gradient but shards the lm-head CONTRACTION dim,
    # forcing a (B,S,V/tp) logits psum over data every forward — measured
    # 0.2-0.6x regressions on train/prefill.  d stays replicated.
    if cfg.frontend == "audio":
        return {"tokens": (None, "tp", None) if v_tp
                else (None, v_fs, "tp")}
    return {"tokens": ("tp", None) if v_tp else (v_fs, "tp")}


def embed_tokens(params: Tree, tokens: jax.Array, cfg: ModelConfig):
    tok = params["tokens"]
    if cfg.frontend == "audio":
        # tokens: (B, S, C) — sum the per-codebook embeddings tok[c] (the
        # EnCodec frontend itself is a stub per the task spec)
        out = 0.0
        for c in range(cfg.n_codebooks):
            out = out + jnp.take(tok[c], tokens[..., c], axis=0)
        return out.astype(cfg.adtype())
    return jnp.take(tok, tokens, axis=0).astype(cfg.adtype())


# ----------------------------- MLP (dense FFN) -----------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Tree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.pdtype()
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k2, d, f, dt),
         "w_down": dense_init(k3, f, d, dt),
         "norm": jnp.ones((d,), dt)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(k1, d, f, dt)
    return p


def mlp_specs(cfg: ModelConfig) -> Tree:
    p = {"w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp"), "norm": (None,)}
    if cfg.act == "swiglu":
        p["w_gate"] = ("fsdp", "tp")
    return p


def mlp(params: Tree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    up = matmul(h, params["w_up"].astype(h.dtype), cfg)
    if cfg.act == "swiglu":
        gate = matmul(h, params["w_gate"].astype(h.dtype), cfg)
        inner = jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype) * up
    else:
        inner = jax.nn.gelu(up.astype(jnp.float32)).astype(h.dtype)
    return x + matmul(inner, params["w_down"].astype(h.dtype), cfg)

"""Attention layers: GQA self-attention (blocked online-softmax), cross-
attention for the VLM frontend, and decode attention with split-KV merging
(flash-decoding) for sequence-sharded caches.

The *blocked* implementation is the default everywhere: it is differentiable,
compiles on any backend, and its peak memory is O(S·block_kv) instead of
O(S²) — which is what makes the 32k-prefill dry-run cells fit.  The Pallas
flash kernel (kernels/flash_attention.py) is the TPU hot path, selected with
``cfg.attn_impl == "pallas"``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, matmul, rms_norm, rope_angles

Tree = Any
NEG_INF = -1e30


# ------------------------------ params -------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Tree:
    d, hd = cfg.d_model, cfg.hd
    dt = cfg.pdtype()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    kv_in = d  # frontend stub provides image embeds already at d_model
    p = {"wq": dense_init(k1, d, cfg.n_heads * hd, dt),
         "wk": dense_init(k2, kv_in, cfg.n_kv_heads * hd, dt),
         "wv": dense_init(k3, kv_in, cfg.n_kv_heads * hd, dt),
         "wo": dense_init(k4, cfg.n_heads * hd, d, dt),
         "norm": jnp.ones((d,), dt)}
    if cross:
        # gate so an untrained cross block is the identity (llama-3.2-vision)
        p["gate"] = jnp.zeros((), dt)
    return p


def attention_specs(cfg: ModelConfig, cross: bool = False) -> Tree:
    p = {"wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
         "wo": ("tp", "fsdp"), "norm": (None,)}
    if cross:
        p["gate"] = ()
    return p


# --------------------------- core attention maths --------------------------

def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool, block_q: int, block_kv: int,
                      q_offset: jax.Array | int = 0) -> jax.Array:
    """Online-softmax attention, O(S·block) memory, differentiable.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D).  GQA via head repetition.
    ``q_offset``: absolute position of q[0] (for decode/chunked prefill).
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = D ** -0.5
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    # pad to block multiples
    pq, pkv = (-Sq) % bq, (-Skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
    nq, nkv = q.shape[1] // bq, k.shape[1] // bkv
    qb = q.reshape(B, nq, bq, H, D).astype(jnp.float32) * scale
    kb = k.reshape(B, nkv, bkv, H, D).astype(jnp.float32)
    vb = v.reshape(B, nkv, bkv, H, D).astype(jnp.float32)

    q_pos = (jnp.arange(nq * bq).reshape(nq, bq) + q_offset)
    k_pos = jnp.arange(nkv * bkv).reshape(nkv, bkv)
    kv_valid = (jnp.arange(nkv * bkv).reshape(nkv, bkv) < Skv)

    def q_block(qi):
        q_i = qb[:, qi]              # (B, bq, H, D)
        pos_q = q_pos[qi]

        def kv_step(carry, kj):
            acc, m, l = carry
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, kb[:, kj])
            mask = kv_valid[kj][None, None, None, :]
            if causal:
                mask = mask & (pos_q[None, None, :, None] >=
                               k_pos[kj][None, None, None, :])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb[:, kj])
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, H, bq, D), jnp.float32)
        m0 = jnp.full((B, H, bq), NEG_INF)
        l0 = jnp.zeros((B, H, bq))
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)  # (B, bq, H, D)

    out = jax.lax.map(q_block, jnp.arange(nq))       # (nq, B, bq, H, D)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, D)
    return out[:, :Sq].astype(q.dtype)


def naive_attention(q, k, v, causal):
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def run_attention(q, k, v, cfg: ModelConfig, causal: bool = True,
                  q_offset=0) -> jax.Array:
    if cfg.attn_impl == "naive":
        return naive_attention(q, k, v, causal)
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        # kernel expects (B, H, S, D)
        out = kops.flash_attention(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3), causal=causal)
        return out.transpose(0, 2, 1, 3)
    return blocked_attention(q, k, v, causal, cfg.attn_block_q,
                             cfg.attn_block_kv, q_offset)


# ------------------------------ layer apply ---------------------------------

def self_attention(params: Tree, x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array | None = None) -> jax.Array:
    """Pre-norm residual GQA self-attention over a full sequence."""
    B, S, d = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    hd = cfg.hd
    q = matmul(h, params["wq"].astype(h.dtype), cfg).reshape(
        B, S, cfg.n_heads, hd)
    k = matmul(h, params["wk"].astype(h.dtype), cfg).reshape(
        B, S, cfg.n_kv_heads, hd)
    v = matmul(h, params["wv"].astype(h.dtype), cfg).reshape(
        B, S, cfg.n_kv_heads, hd)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    sin, cos = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    out = run_attention(q, k, v, cfg, causal=True)
    out = matmul(out.reshape(B, S, cfg.n_heads * hd),
                 params["wo"].astype(h.dtype), cfg)
    return x + out, (k, v)


def cross_attention(params: Tree, x: jax.Array, kv_embeds: jax.Array,
                    cfg: ModelConfig,
                    kv_cache: tuple | None = None) -> jax.Array:
    """Gated cross-attention to frontend embeddings (no RoPE, not causal)."""
    B, S, d = x.shape
    hd = cfg.hd
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q = matmul(h, params["wq"].astype(h.dtype), cfg).reshape(
        B, S, cfg.n_heads, hd)
    if kv_cache is not None:
        k, v = kv_cache
    else:
        k = matmul(kv_embeds, params["wk"].astype(h.dtype), cfg).reshape(
            B, -1, cfg.n_kv_heads, hd)
        v = matmul(kv_embeds, params["wv"].astype(h.dtype), cfg).reshape(
            B, -1, cfg.n_kv_heads, hd)
    out = run_attention(q, k, v, cfg, causal=False)
    out = matmul(out.reshape(B, S, cfg.n_heads * hd),
                 params["wo"].astype(h.dtype), cfg)
    gate = jnp.tanh(params["gate"].astype(jnp.float32)).astype(out.dtype)
    return x + gate * out, (k, v)


# ------------------------------ decode path ---------------------------------

def decode_self_attention(params: Tree, x: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, pos: jax.Array,
                          cfg: ModelConfig, seq_shards: int = 1,
                          axis_name: str | None = None,
                          kv_spec: tuple | None = None):
    """One-token decode step against a KV cache.

    x: (B, 1, D); caches: (B, S_max, Hkv, hd); pos: (B,) current lengths.
    When the cache is sequence-sharded (long_500k: batch < data axis), this
    runs under shard_map and merges per-shard partial attention with a
    log-sum-exp reduction over ``axis_name`` (flash-decoding).

    ``kv_spec``: the cache's logical sharding.  The freshly-projected K/V
    arrive sharded by the weight layout ((kv·hd)/tp columns); constraining
    the 1-token k_new/v_new to the CACHE layout before the in-place update
    moves the reshard from the whole cache to the new token — this removed
    GSPMD's per-step "involuntary full rematerialization" cache copies
    (EXPERIMENTS.md §Perf hillclimb A).
    """
    from repro.parallel import ctx
    B, _, d = x.shape
    hd = cfg.hd
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    q = matmul(h, params["wq"].astype(h.dtype), cfg).reshape(
        B, 1, cfg.n_heads, hd)
    k_new = matmul(h, params["wk"].astype(h.dtype), cfg).reshape(
        B, 1, cfg.n_kv_heads, hd)
    v_new = matmul(h, params["wv"].astype(h.dtype), cfg).reshape(
        B, 1, cfg.n_kv_heads, hd)
    sin, cos = rope_angles(pos[:, None], hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k_new = apply_rope(k_new, sin, cos)
    if kv_spec is not None:
        k_new = ctx.shard(k_new, *kv_spec)
        v_new = ctx.shard(v_new, *kv_spec)
        # align q with the cache layout as well: when the cache shards
        # head_dim, a head-sharded q would force GSPMD to all-gather the
        # whole cache per step (hillclimb A2) — with q on the same layout
        # the qk contraction is shard-wise + a tiny psum of the scores.
        q = ctx.shard(q, *kv_spec)

    S_local = k_cache.shape[1]
    if axis_name is None:
        # cache local to this shard: write the new token, attend to prefix
        k_cache = jax.vmap(
            lambda c, kn, p: jax.lax.dynamic_update_slice(c, kn, (p, 0, 0))
        )(k_cache, k_new, pos)
        v_cache = jax.vmap(
            lambda c, vn, p: jax.lax.dynamic_update_slice(c, vn, (p, 0, 0))
        )(v_cache, v_new, pos)
        valid = jnp.arange(S_local)[None, :] <= pos[:, None]   # (B, S)
        out = _masked_decode_attn(q, k_cache, v_cache, valid, cfg,
                                  kv_spec=kv_spec)
    else:
        # sequence-sharded cache: each shard owns rows
        # [shard*S_local, (shard+1)*S_local); only the owner writes the token
        if isinstance(axis_name, tuple):
            shard = jnp.int32(0)
            for ax in axis_name:  # row-major linearized multi-axis index
                shard = shard * jax.lax.axis_size(ax) + \
                    jax.lax.axis_index(ax)
        else:
            shard = jax.lax.axis_index(axis_name)
        local_pos = pos - shard * S_local
        own = (local_pos >= 0) & (local_pos < S_local)
        lp = jnp.clip(local_pos, 0, S_local - 1)
        upd = lambda c, n, p, o: jax.lax.dynamic_update_slice(
            c, jnp.where(o, n, jax.lax.dynamic_slice(
                c, (p, 0, 0), n.shape)), (p, 0, 0))
        k_cache = jax.vmap(upd)(k_cache, k_new, lp, own)
        v_cache = jax.vmap(upd)(v_cache, v_new, lp, own)
        gpos = jnp.arange(S_local)[None, :] + shard * S_local
        valid = gpos <= pos[:, None]
        m, l, o_part = _partial_decode_attn(q, k_cache, v_cache, valid, cfg)
        # LSE merge across shards
        m_glob = jax.lax.pmax(m, axis_name)
        w = jnp.exp(m - m_glob)
        l_glob = jax.lax.psum(l * w, axis_name)
        out = jax.lax.psum(o_part * w[..., None], axis_name) / \
            jnp.maximum(l_glob, 1e-30)[..., None]
        out = out.transpose(0, 2, 1, 3).astype(x.dtype)

    out = matmul(out.reshape(B, 1, cfg.n_heads * hd),
                 params["wo"].astype(h.dtype), cfg)
    return x + out, k_cache, v_cache


def _expand_kv(k, v, H):
    rep = H // k.shape[2]
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


def _masked_decode_attn(q, k, v, valid, cfg, kv_spec=None):
    """q: (B,1,H,hd); k/v: (B,S,Hkv,hd); valid: (B,S) -> (B,1,H,hd).

    Grouped-head einsum: GQA without ``jnp.repeat`` — repeating kv-heads
    would materialize (and under hd-sharding, all-gather) group× the cache
    (hillclimb A3).  With ``kv_spec`` the softmax weights are explicitly
    replicated so the p·v contraction stays shard-wise on head_dim.
    """
    from repro.parallel import ctx
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    # keep the cache in its storage dtype and accumulate in f32
    # (hillclimb A4: astype(f32) on the cache materializes 2x cache bytes
    # per layer per step) — softmax itself stays f32.
    qg = q.reshape(B, 1, Hkv, g, hd).astype(k.dtype)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * (cfg.hd ** -0.5)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)              # (B, Hkv, g, 1, S)
    if kv_spec is not None:
        p = ctx.shard(p, kv_spec[0], None, None, None, None)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def _partial_decode_attn(q, k, v, valid, cfg):
    """Per-shard partial softmax stats (m, l, unnormalized o)."""
    k, v = _expand_kv(k, v, cfg.n_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (cfg.hd ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)                          # (B, H, 1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)                          # (B, H, 1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p, v.astype(jnp.float32))
    return m, l, o

"""Token-choice top-k Mixture-of-Experts with expert parallelism.

Dispatch is the sort-based capacity scheme (no (T, E, C) one-hot tensors —
those are impossible at E=384): flatten (token, k) assignments, sort by
expert, compute the position-in-expert by segment offsets, drop beyond
capacity, scatter into an (E, C, D) buffer, run the batched expert FFN, and
combine back with router weights.

Distribution: experts shard over the "tp"/model mesh axis.  Two paths:

  * ``moe_ffn`` — single-shard math (smoke tests, and the pjit fallback
    where GSPMD inserts the collectives for the sharded expert einsums).
  * ``moe_ffn_ep`` — explicit shard_map: every model shard routes the
    (replicated) token block, computes ONLY its local experts and psums the
    partial combine — the collective-light EP scheme whose roofline term is
    analyzed in EXPERIMENTS.md (§Perf iterates on it).

Aux losses: the standard load-balancing loss (mean_e f_e · p_e · E) is
returned so train steps can add it.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, matmul, rms_norm

Tree = Any


def init_moe(key, cfg: ModelConfig) -> Tree:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    dt = cfg.pdtype()
    ks = jax.random.split(key, 4)
    scale = 1.0 / (d ** 0.5)
    mk = lambda k, shape: (jax.random.normal(k, shape, jnp.float32)
                           * scale).astype(dt)
    return {
        "norm": jnp.ones((d,), dt),
        "router": dense_init(ks[0], d, E, jnp.float32),  # router in fp32
        "w_gate": mk(ks[1], (E, d, f)),
        "w_up": mk(ks[2], (E, d, f)),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                   / (f ** 0.5)).astype(dt),
    }


def moe_specs(cfg: ModelConfig) -> Tree:
    return {"norm": (None,), "router": ("fsdp", None),
            "w_gate": ("tp", "fsdp", None), "w_up": ("tp", "fsdp", None),
            "w_down": ("tp", None, "fsdp")}


def _route(h2: jax.Array, router: jax.Array, top_k: int):
    """h2: (T, D) -> (weights (T,K), ids (T,K), aux_loss)."""
    logits = h2.astype(jnp.float32) @ router.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    E = router.shape[1]
    # load-balance aux: E * Σ_e fraction_e * prob_e
    frac = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        1.0 / ids.size)
    aux = E * jnp.sum(frac * probs.mean(0))
    return w, ids, aux


def _dispatch_indices(ids: jax.Array, E: int, capacity: int):
    """Sort-based positions.  ids: (T, K) -> scatter indices + keep mask."""
    TK = ids.size
    flat_e = ids.reshape(-1)
    order = jnp.argsort(flat_e)                      # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(TK) - seg_start                 # position within expert
    keep = pos < capacity
    buf_idx = sorted_e * capacity + jnp.minimum(pos, capacity - 1)
    return order, buf_idx, keep


def _expert_ffn(buf: jax.Array, params: Tree, cfg: ModelConfig) -> jax.Array:
    """buf: (E, C, D) -> (E, C, D) batched expert SwiGLU."""
    w_g = params["w_gate"].astype(buf.dtype)
    w_u = params["w_up"].astype(buf.dtype)
    w_d = params["w_down"].astype(buf.dtype)
    gate = jnp.einsum("ecd,edf->ecf", buf, w_g)
    up = jnp.einsum("ecd,edf->ecf", buf, w_u)
    inner = jax.nn.silu(gate.astype(jnp.float32)).astype(buf.dtype) * up
    return jnp.einsum("ecf,efd->ecd", inner, w_d)


def _capacity(T: int, cfg: ModelConfig, full: bool) -> int:
    m = cfg.moe
    if full:
        return T  # decode: an expert can receive every token — no drops
    return int(max(1, T * m.top_k * m.capacity_factor / m.n_experts))


def moe_ffn(params: Tree, x: jax.Array, cfg: ModelConfig,
            full_capacity: bool = False):
    """Single-shard / pjit path.  x: (B, S, D) -> (B, S, D), aux."""
    B, S, d = x.shape
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    out, aux = _moe_math_dyn(h.reshape(B * S, d), params, cfg, 0,
                             cfg.moe.n_experts,
                             capacity=_capacity(B * S, cfg, full_capacity))
    return x + out.reshape(B, S, d), aux


def moe_ffn_ep(params: Tree, x: jax.Array, cfg: ModelConfig,
               mesh, model_axis: str = "model",
               full_capacity: bool = False):
    """Explicit expert-parallel path (shard_map over the model axis).

    Token block is replicated across the model axis; every shard computes
    its local expert slice and the combine is a psum — collective cost is
    one (B,S,D) psum, identical to a TP FFN reduce, with no (T,E,C) tensor
    ever materialized globally.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    m = cfg.moe
    n_shards = mesh.shape[model_axis]
    assert m.n_experts % n_shards == 0
    e_per = m.n_experts // n_shards

    def local(x_l, norm, router, w_gate, w_up, w_down):
        B, S, d = x_l.shape
        p_l = {"norm": norm, "router": router, "w_gate": w_gate,
               "w_up": w_up, "w_down": w_down}
        shard = jax.lax.axis_index(model_axis)
        h = rms_norm(x_l, norm, cfg.norm_eps)
        e_lo = shard * e_per
        out, aux = _moe_math_dyn(h.reshape(B * S, d), p_l, cfg, e_lo, e_per,
                                 capacity=_capacity(B * S, cfg,
                                                    full_capacity))
        out = jax.lax.psum(out, model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        return x_l + out.reshape(B, S, d), aux

    # shard the token batch over data only when divisible (decode at tiny
    # batch replicates tokens instead — the expert math still splits over
    # the model axis)
    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    data_spec = (P(dp, None, None) if x.shape[0] % dp_size == 0
                 else P(None, None, None))
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(data_spec, P(None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(data_spec, P()),
        check_rep=False)
    return fn(x, params["norm"], params["router"], params["w_gate"],
              params["w_up"], params["w_down"])


def _moe_math_dyn(h2, params, cfg, e_lo, e_per: int, capacity: int):
    """Dispatch->ffn->combine for experts [e_lo, e_lo+e_per) (e_lo may be a
    traced shard_map axis_index)."""
    m = cfg.moe
    w, ids, aux = _route(h2, params["router"], m.top_k)
    order, buf_idx, keep = _dispatch_indices(ids, m.n_experts, capacity)
    sorted_e = ids.reshape(-1)[order]
    local = (sorted_e >= e_lo) & (sorted_e < e_lo + e_per)
    keep = keep & local
    buf_idx = buf_idx - e_lo * capacity
    buf_idx = jnp.where(keep, buf_idx, e_per * capacity)
    tok_idx = order // m.top_k
    gathered = h2[tok_idx] * keep[:, None].astype(h2.dtype)
    buf = jnp.zeros((e_per * capacity + 1, h2.shape[1]), h2.dtype)
    buf = buf.at[buf_idx].set(gathered)
    out_buf = _expert_ffn(buf[:-1].reshape(e_per, capacity, -1), params, cfg)
    out_buf = jnp.concatenate(
        [out_buf.reshape(e_per * capacity, -1),
         jnp.zeros((1, h2.shape[1]), h2.dtype)], axis=0)
    back = out_buf[jnp.where(keep, buf_idx, e_per * capacity)]
    wk = w.reshape(-1)[order] * keep.astype(jnp.float32)
    out = jnp.zeros_like(h2).at[tok_idx].add(
        back * wk[:, None].astype(h2.dtype))
    return out, aux

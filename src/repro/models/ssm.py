"""Mamba-2 (SSD — state-space duality) mixer, chunked-scan formulation.

Faithful to the SSD algorithm of arXiv:2405.21060: within a chunk the
recurrence is computed in its quadratic "attention" dual form (MXU-friendly
Q×Q einsums); across chunks a sequential scan carries the (H, P, N) state.
Peak memory is O(B·H·Q²) for ONE chunk because the chunk loop is a
``lax.scan`` — this is what makes the 500k-context cells tractable, and
decode is O(1) in sequence length (conv tail + SSM state only).

TP sharding: heads/d_inner columns shard over "tp"; the (small) B/C group
projections are replicated, so the depthwise conv is split into a sharded x
conv and a replicated bc conv (see DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, matmul, rms_norm

Tree = Any


class SSMState(NamedTuple):
    conv_x: jax.Array    # (B, convw-1, d_inner)
    conv_bc: jax.Array   # (B, convw-1, 2*G*N)
    ssm: jax.Array       # (B, H, P, N) float32


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.headdim
    return d_inner, H, s.headdim, s.n_groups, s.d_state


def init_ssm(key, cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    s = cfg.ssm
    d_inner, H, P, G, N = dims(cfg)
    dt = cfg.pdtype()
    ks = jax.random.split(key, 8)
    # init dt bias so softplus(dt_bias) spans [dt_min, dt_max] (mamba2 init)
    u = jax.random.uniform(ks[6], (H,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min))
                  + math.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "norm": jnp.ones((d,), dt),
        "w_z": dense_init(ks[0], d, d_inner, dt),
        "w_x": dense_init(ks[1], d, d_inner, dt),
        "w_bc": dense_init(ks[2], d, 2 * G * N, dt),
        "w_dt": dense_init(ks[3], d, H, dt),
        "dt_bias": dt_bias.astype(dt),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dt),
        "d_skip": jnp.ones((H,), dt),
        "conv_x_w": (jax.random.normal(ks[4], (s.conv_width, d_inner),
                                       jnp.float32) * 0.1).astype(dt),
        "conv_bc_w": (jax.random.normal(ks[5], (s.conv_width, 2 * G * N),
                                        jnp.float32) * 0.1).astype(dt),
        "gate_norm": jnp.ones((d_inner,), dt),
        "w_out": dense_init(ks[7], d_inner, d, dt),
    }


def ssm_specs(cfg: ModelConfig) -> Tree:
    return {
        "norm": (None,), "w_z": ("fsdp", "tp"), "w_x": ("fsdp", "tp"),
        "w_bc": ("fsdp", None), "w_dt": ("fsdp", "tp"), "dt_bias": ("tp",),
        "a_log": ("tp",), "d_skip": ("tp",),
        "conv_x_w": (None, "tp"), "conv_bc_w": (None, None),
        "gate_norm": ("tp",), "w_out": ("tp", "fsdp"),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           tail: jax.Array | None = None) -> jax.Array:
    """x: (B, S, C); w: (convw, C); optional tail: (B, convw-1, C)."""
    convw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], convw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(convw))
    return out


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B,S,H,P), dt: (B,S,H) (already softplus'd), A: (H,) < 0,
    Bm/Cm: (B,S,G,N).  Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = xh.shape[1] // Q
    rep = H // G  # heads per group

    xh = xh.reshape(Bsz, nc, Q, H, P).astype(jnp.float32)
    dt = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, nc, Q, G, N).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, nc, Q, G, N).astype(jnp.float32)
    # expand groups to heads (G is small; rep is static)
    Bh = jnp.repeat(Bm, rep, axis=3)       # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cm, rep, axis=3)

    dA = dt * A[None, None, None, :]                    # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(dA, axis=2)                        # inclusive
    state0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def chunk_step(state, c):
        x_c, dt_c = xh[:, c], dt[:, c]
        B_c, C_c = Bh[:, c], Ch[:, c]
        cum_c, dA_c = cum[:, c], dA[:, c]
        # off-diagonal: contribution of the incoming state
        decay_in = jnp.exp(cum_c)                       # (B,Q,H)
        y_off = jnp.einsum("bqhn,bhpn,bqh->bqhp", C_c, state, decay_in)
        # diagonal: within-chunk dual (quadratic) form
        seg = cum_c[:, :, None, :] - cum_c[:, None, :, :]   # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bqhn,bshn->bqsh", C_c, B_c)        # (B,Q,Q,H)
        y_diag = jnp.einsum("bqsh,bsh,bshp->bqhp", cb * L, dt_c, x_c)
        # state passed to the next chunk
        decay_out = jnp.exp(cum_c[:, -1:, :] - cum_c)       # (B,Q,H)
        state_new = jnp.einsum("bqhn,bqh,bqhp->bhpn",
                               B_c, decay_out * dt_c, x_c)
        chunk_decay = jnp.exp(cum_c[:, -1, :])              # (B,H)
        state = state * chunk_decay[:, :, None, None] + state_new
        return state, y_off + y_diag

    state, ys = jax.lax.scan(chunk_step, state0, jnp.arange(nc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, nc * Q, H, P)
    return y[:, :S], state


def ssm_forward(params: Tree, x: jax.Array, cfg: ModelConfig,
                state: SSMState | None = None,
                return_state: bool = False):
    """Full-sequence mamba2 mixer (train / prefill)."""
    Bsz, S, d = x.shape
    s = cfg.ssm
    d_inner, H, P, G, N = dims(cfg)
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    z = matmul(h, params["w_z"].astype(h.dtype), cfg)
    xin = matmul(h, params["w_x"].astype(h.dtype), cfg)
    bc = matmul(h, params["w_bc"].astype(h.dtype), cfg)
    dt_raw = matmul(h, params["w_dt"].astype(h.dtype), cfg)

    tail_x = state.conv_x if state is not None else None
    tail_bc = state.conv_bc if state is not None else None
    xin = jax.nn.silu(_causal_depthwise_conv(
        xin, params["conv_x_w"].astype(h.dtype), tail_x).astype(jnp.float32))
    bc = jax.nn.silu(_causal_depthwise_conv(
        bc, params["conv_bc_w"].astype(h.dtype), tail_bc).astype(jnp.float32))
    Bm, Cm = jnp.split(bc.reshape(Bsz, S, 2 * G, N), 2, axis=2)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xin.reshape(Bsz, S, H, P)
    init = state.ssm if state is not None else None
    y, final_state = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk, init)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(Bsz, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 params["gate_norm"], cfg.norm_eps).astype(x.dtype)
    out = x + matmul(y, params["w_out"].astype(x.dtype), cfg)
    if not return_state:
        return out, None
    convw = s.conv_width
    # conv tails: last convw-1 pre-activation conv inputs
    h_x = matmul(h, params["w_x"].astype(h.dtype), cfg)
    h_bc = matmul(h, params["w_bc"].astype(h.dtype), cfg)
    new_state = SSMState(
        conv_x=h_x[:, -(convw - 1):, :],
        conv_bc=h_bc[:, -(convw - 1):, :],
        ssm=final_state)
    return out, new_state


def ssm_decode_step(params: Tree, x: jax.Array, state: SSMState,
                    cfg: ModelConfig):
    """Single-token decode: O(1) state update.  x: (B, 1, D)."""
    Bsz = x.shape[0]
    s = cfg.ssm
    d_inner, H, P, G, N = dims(cfg)
    h = rms_norm(x, params["norm"], cfg.norm_eps)
    z = matmul(h, params["w_z"].astype(h.dtype), cfg)[:, 0]
    xin_pre = matmul(h, params["w_x"].astype(h.dtype), cfg)     # (B,1,din)
    bc_pre = matmul(h, params["w_bc"].astype(h.dtype), cfg)
    dt_raw = matmul(h, params["w_dt"].astype(h.dtype), cfg)[:, 0]

    # conv via stored tails
    cx = jnp.concatenate([state.conv_x.astype(h.dtype), xin_pre], axis=1)
    cbc = jnp.concatenate([state.conv_bc.astype(h.dtype), bc_pre], axis=1)
    w_x, w_bc = params["conv_x_w"].astype(h.dtype), params["conv_bc_w"].astype(h.dtype)
    xin = jax.nn.silu(jnp.einsum("bwc,wc->bc", cx, w_x).astype(jnp.float32))
    bc = jax.nn.silu(jnp.einsum("bwc,wc->bc", cbc, w_bc).astype(jnp.float32))
    Bm, Cm = jnp.split(bc.reshape(Bsz, 2 * G, N), 2, axis=1)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                                # (B,H)
    xh = xin.reshape(Bsz, H, P)
    ssm = state.ssm * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, ssm)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)),
                 params["gate_norm"], cfg.norm_eps).astype(x.dtype)
    out = x + matmul(y, params["w_out"].astype(x.dtype), cfg)[:, None, :]
    new_state = SSMState(conv_x=cx[:, 1:].astype(state.conv_x.dtype),
                         conv_bc=cbc[:, 1:].astype(state.conv_bc.dtype),
                         ssm=ssm)
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    d_inner, H, P, G, N = dims(cfg)
    return SSMState(
        conv_x=jnp.zeros((batch, s.conv_width - 1, d_inner), dtype),
        conv_bc=jnp.zeros((batch, s.conv_width - 1, 2 * G * N), dtype),
        ssm=jnp.zeros((batch, H, P, N), jnp.float32))

"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated against ref.py in interpret mode, per
the task brief).  On a TPU backend the wrappers run compiled Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.genome import CGPSpec, Genome
from repro.kernels import cgp_sim as _cgp
from repro.kernels import flash_attention as _fa
from repro.kernels import lut_matmul as _lut
from repro.kernels import tune as _tune


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


_INTERPRET_DEFAULT: bool | None = None


def default_interpret() -> bool:
    """Process-wide interpret-mode default for every kernel wrapper.

    Resolved from ``jax.default_backend()`` ONCE, at the first call, and
    cached for the life of the process.  ``jax.default_backend()`` itself is
    insensitive to trace context (it reads the process platform config, not
    e.g. a ``jax.default_device`` scope), so resolving it during tracing is
    safe — the trap this cache closes is the report CHANGING between traces
    (a ``jax.config.update("jax_platform_name", ...)`` after the first
    evolve trace was built): per-call resolution would bake different modes
    into different cached traces of the same program.  One pinned resolution
    makes every trace in the process agree.  (No eager import-time pin: that
    would force backend initialization as an import side effect.)
    """
    global _INTERPRET_DEFAULT
    if _INTERPRET_DEFAULT is None:
        _INTERPRET_DEFAULT = not _on_tpu()
    return _INTERPRET_DEFAULT


def _partials_from_sums(sums: jax.Array, wce: jax.Array, hist: jax.Array
                        ) -> M.MetricPartials:
    """Decode the kernel's (..., N_SUMS) split-sum rows into MetricPartials."""
    C = _cgp
    return M.MetricPartials(
        abs_sum=256.0 * sums[..., C.ABS_HI] + sums[..., C.ABS_LO],
        wce_max=wce[..., 0],
        err_count=sums[..., C.ERR_CNT].astype(jnp.int32),
        rel_sum=sums[..., C.REL_SUM],
        sgn_sum=(256.0 * sums[..., C.POS_HI] + sums[..., C.POS_LO])
                - (256.0 * sums[..., C.NEG_HI] + sums[..., C.NEG_LO]),
        acc0_bad=sums[..., C.ACC0_BAD].astype(jnp.int32),
        hist=hist.astype(jnp.int32),
        count=sums[..., C.COUNT].astype(jnp.int32),
        sq_sum=sums[..., C.SQ_SUM],
        rel_sq=sums[..., C.REL_SQ],
    )


def cgp_eval(genome: Genome, spec: CGPSpec, in_planes: jax.Array,
             golden_vals: jax.Array, gauss_sigma: float = 256.0,
             block_words: int = 512, interpret: bool | None = None
             ) -> tuple[M.MetricPartials, jax.Array]:
    """Fused candidate evaluation -> (MetricPartials, per-gate popcounts).

    Drop-in for ref.cgp_eval_ref; used by core.evolve backend="pallas".
    """
    if interpret is None:
        interpret = default_interpret()
    sums, wce, hist, pops = _cgp.cgp_sim_metrics(
        genome.nodes, genome.outs, in_planes, golden_vals,
        n_i=spec.n_i, n_n=spec.n_n, n_o=spec.n_o,
        gauss_sigma=gauss_sigma, block_words=block_words,
        interpret=interpret)
    return _partials_from_sums(sums, wce, hist), pops


def cgp_eval_batched(genomes: Genome, spec: CGPSpec, in_planes: jax.Array,
                     golden_vals: jax.Array, gauss_sigma: float = 256.0,
                     block_words: int | None = None,
                     interpret: bool | None = None,
                     r_tile: int | None = None, axis_name: str | None = None,
                     layout: str = "auto"
                     ) -> tuple[M.MetricPartials, jax.Array]:
    """Fused (runs × λ) population evaluation in ONE kernel dispatch.

    ``genomes`` carries a leading stacked axis R: nodes (R, n_n, 3), outs
    (R, n_o).  The genome axis becomes Pallas grid dimension 0 — this
    replaces ``jax.vmap(cgp_eval)`` over a population, which dispatched one
    kernel per genome (or one vmap-batched program) and left the run axis
    off the grid.  Returns (MetricPartials with leading R, pops (R, n_n)).

    With ``axis_name`` the input cube is sharded over that mesh axis:
    ``in_planes``/``golden_vals`` are this shard's word slice, the local
    dispatch is unchanged, and the per-genome accumulators are combined
    across the axis before decoding (``cgp_sim_metrics_batched_sharded`` —
    psum for the sums/histogram/popcount rows, pmax for WCE), so the
    returned partials and popcounts are already cube-global.  Only callable
    where the axis is bound (e.g. under ``shard_map``).

    ``block_words=None`` / ``r_tile=None`` pick the kernel execution point
    automatically: under ``layout="auto"`` the whole MEASURED winner variant
    is adopted — layout AND block size AND genome-axis pad together, since
    the tuning pass times them jointly (``kernels.tune.resolve_variant``,
    keyed by (width, R, backend); a half-adopted variant could be slower
    than either the winner or the default).  With an explicit layout, or no
    table entry, the defaults are 512 words and the interpret-aware pad
    (sublane padding only helps the Mosaic lowering, while interpret mode
    pays every pad row as a full recomputed evaluation — so 8 when
    compiled, 1 interpreted).  Passing either knob explicitly overrides the
    tuned value for that knob only.

    ``layout`` picks the evaluation-grid order (DESIGN.md §7):
    ``"genome_major"`` (cube innermost per genome) or ``"cube_major"``
    (transposed grid — one cube block reused across the whole population,
    per-genome accumulators in flushed VMEM scratch).  Results are
    bit-identical either way.  The default ``"auto"`` resolves through the
    measured tuning table; with no table entry it falls back to
    genome-major.  Resolution happens at trace time (R and the backend are
    static), so it costs nothing per step.
    """
    if interpret is None:
        interpret = default_interpret()
    variant = None
    if layout == "auto":
        # on a full table miss, fall back to the same execution point an
        # explicit layout would get (incl. the interpret-aware pad)
        variant = _tune.resolve_variant(
            spec.n_i // 2, genomes.nodes.shape[0],
            _tune.backend_key(interpret),
            default=_tune.KernelVariant(r_tile=1 if interpret else 8))
        layout = variant.layout
    if block_words is None:
        # tuned blocks are measured per width, and every candidate is a
        # power of two, so they divide any (power-of-two) cube shard too
        block_words = variant.block_words if variant is not None else 512
    if r_tile is None:
        r_tile = variant.r_tile if variant is not None \
            else (1 if interpret else 8)
    kw = dict(n_i=spec.n_i, n_n=spec.n_n, n_o=spec.n_o,
              gauss_sigma=gauss_sigma, block_words=block_words,
              r_tile=r_tile, layout=layout, interpret=interpret)
    if axis_name is None:
        sums, wce, hist, pops = _cgp.cgp_sim_metrics_batched(
            genomes.nodes, genomes.outs, in_planes, golden_vals, **kw)
    else:
        sums, wce, hist, pops = _cgp.cgp_sim_metrics_batched_sharded(
            genomes.nodes, genomes.outs, in_planes, golden_vals,
            axis_name=axis_name, **kw)
    return _partials_from_sums(sums, wce, hist), pops


def lut_matmul(a: jax.Array, b: jax.Array, lut: jax.Array,
               interpret: bool | None = None, **tiles) -> jax.Array:
    """Approximate-multiplier emulated matmul (pads to tile multiples).

    Arbitrary (M, N, K) are accepted: operands are zero-padded up to the
    tile grid and the output sliced back.  Zero-padding the contraction dim
    is NOT free under an approximate LUT — every padded k contributes
    ``LUT[0, 0]`` (an evolved circuit need not map 0×0 to 0) — so the
    ``pad_k * LUT[0, 0]`` bias is subtracted from every output element,
    keeping ragged shapes bit-identical to the unpadded LUT contraction.
    """
    if interpret is None:
        interpret = default_interpret()
    M_, K = a.shape
    _, N = b.shape
    bm = min(tiles.get("bm", 128), max(8, M_))
    bn = min(tiles.get("bn", 128), max(8, N))
    bk = min(tiles.get("bk", 128), max(8, K))
    pm, pn, pk = (-M_) % bm, (-N) % bn, (-K) % bk
    a_p = jnp.pad(a, ((0, pm), (0, pk)))
    b_p = jnp.pad(b, ((0, pk), (0, pn)))
    out = _lut.lut_matmul(a_p, b_p, lut, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    out = out[:M_, :N]
    if pk:
        out = out - pk * lut[0, 0].astype(out.dtype)
    return out


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, interpret: bool | None = None,
                    bq: int = 128, bkv: int = 128) -> jax.Array:
    """Blocked attention; q (B, Hq, S, D), k/v (B, Hkv, S, D); GQA folded.

    Heads are grouped: q-heads h use kv-head h // (Hq // Hkv).
    """
    if interpret is None:
        interpret = default_interpret()
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    qf = q.reshape(B * Hq, Sq, D)
    kf = jnp.repeat(k, group, axis=1).reshape(B * Hq, Skv, D)
    vf = jnp.repeat(v, group, axis=1).reshape(B * Hq, Skv, D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal,
                              bq=bq, bkv=bkv, interpret=interpret)
    return out.reshape(B, Hq, Sq, D)

"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and are validated against ref.py in interpret mode, per
the task brief).  On a TPU backend the wrappers run compiled Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.genome import CGPSpec, Genome
from repro.kernels import cgp_sim as _cgp
from repro.kernels import flash_attention as _fa
from repro.kernels import lut_matmul as _lut


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cgp_eval(genome: Genome, spec: CGPSpec, in_planes: jax.Array,
             golden_vals: jax.Array, gauss_sigma: float = 256.0,
             block_words: int = 512, interpret: bool | None = None
             ) -> tuple[M.MetricPartials, jax.Array]:
    """Fused candidate evaluation -> (MetricPartials, per-gate popcounts).

    Drop-in for ref.cgp_eval_ref; used by core.evolve backend="pallas".
    """
    if interpret is None:
        interpret = not _on_tpu()
    sums, wce, hist, pops = _cgp.cgp_sim_metrics(
        genome.nodes, genome.outs, in_planes, golden_vals,
        n_i=spec.n_i, n_n=spec.n_n, n_o=spec.n_o,
        gauss_sigma=gauss_sigma, block_words=block_words,
        interpret=interpret)
    C = _cgp
    partials = M.MetricPartials(
        abs_sum=256.0 * sums[C.ABS_HI] + sums[C.ABS_LO],
        wce_max=wce[0],
        err_count=sums[C.ERR_CNT].astype(jnp.int32),
        rel_sum=sums[C.REL_SUM],
        sgn_sum=(256.0 * sums[C.POS_HI] + sums[C.POS_LO])
                - (256.0 * sums[C.NEG_HI] + sums[C.NEG_LO]),
        acc0_bad=sums[C.ACC0_BAD].astype(jnp.int32),
        hist=hist.astype(jnp.int32),
        count=sums[C.COUNT].astype(jnp.int32),
    )
    return partials, pops


def lut_matmul(a: jax.Array, b: jax.Array, lut: jax.Array,
               interpret: bool | None = None, **tiles) -> jax.Array:
    """Approximate-multiplier emulated matmul (pads to tile multiples)."""
    if interpret is None:
        interpret = not _on_tpu()
    M_, K = a.shape
    _, N = b.shape
    bm = min(tiles.get("bm", 128), max(8, M_))
    bn = min(tiles.get("bn", 128), max(8, N))
    bk = min(tiles.get("bk", 128), max(8, K))
    pm, pn, pk = (-M_) % bm, (-N) % bn, (-K) % bk
    a_p = jnp.pad(a, ((0, pm), (0, pk)))
    b_p = jnp.pad(b, ((0, pk), (0, pn)))
    out = _lut.lut_matmul(a_p, b_p, lut, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    return out[:M_, :N]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, interpret: bool | None = None,
                    bq: int = 128, bkv: int = 128) -> jax.Array:
    """Blocked attention; q (B, Hq, S, D), k/v (B, Hkv, S, D); GQA folded.

    Heads are grouped: q-heads h use kv-head h // (Hq // Hkv).
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    qf = q.reshape(B * Hq, Sq, D)
    kf = jnp.repeat(k, group, axis=1).reshape(B * Hq, Skv, D)
    vf = jnp.repeat(v, group, axis=1).reshape(B * Hq, Skv, D)
    out = _fa.flash_attention(qf, kf, vf, causal=causal,
                              bq=bq, bkv=bkv, interpret=interpret)
    return out.reshape(B, Hq, Sq, D)

"""Approximate-multiplier LUT matmul Pallas kernel (deployment bridge).

On silicon the evolved CGP circuit *is* the multiplier inside a MAC array
(paper ref. [4]: approximate multipliers for neural networks — the use case
that motivates the ACC0 metric).  On TPU we cannot swap the MXU's multiplier,
so this kernel *emulates* the evolved circuit exactly: every elementwise
product in an int8×int8 matmul is looked up in the circuit's 256×256 product
table, which lives in VMEM (256 KB) for the whole kernel.

    C[m, n] = Σ_k LUT[A[m, k], B[k, n]]      (uint8 operands, int32 accum)

This kernel exists for *emulation fidelity* (model-accuracy studies of
approximate arithmetic), not for speed — a gather per MAC can never beat the
MXU.  That trade-off is stated in DESIGN.md/EXPERIMENTS.md wherever it is
used; the exact-LUT case is cross-checked against a real int8 matmul.

Tiling: grid (M/BM, N/BN, K/BK); A/B blocks stream through VMEM; the int32
accumulator tile is a revisited output block over the K grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def lut_matmul_kernel(a_ref, b_ref, lut_ref, c_ref, *, bk: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    a = a_ref[...].astype(jnp.int32)   # (BM, BK) in [0, 255]
    b = b_ref[...].astype(jnp.int32)   # (BK, BN)
    lut_flat = lut_ref[...].reshape(-1)  # (65536,) int32 in VMEM

    def body(kk, acc):
        idx = a[:, kk][:, None] * 256 + b[kk, :][None, :]   # (BM, BN)
        return acc + jnp.take(lut_flat, idx, axis=0)

    acc = jax.lax.fori_loop(0, bk, body, jnp.zeros_like(c_ref))
    c_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_matmul(a: jax.Array, b: jax.Array, lut: jax.Array,
               *, bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = True) -> jax.Array:
    """C = LUT-matmul(A, B).  A: (M, K) uint8/int32, B: (K, N), LUT: (256,256).

    This is the raw tiled kernel: shapes must tile evenly.  Use
    ``kernels.ops.lut_matmul`` for arbitrary shapes — it pads to the tile
    grid, slices back, and corrects the K-padding ``LUT[0, 0]`` bias an
    approximate table introduces.
    """
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: A is (M={M}, K={K}), "
                         f"B is (K={K2}, N={N})")
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(
            f"shapes must tile evenly: (M={M}, N={N}, K={K}) vs tiles "
            f"(bm={bm}, bn={bn}, bk={bk}) — use kernels.ops.lut_matmul, "
            f"which pads and corrects the LUT[0,0] bias")

    kernel = functools.partial(lut_matmul_kernel, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((256, 256), lambda m, n, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(a.astype(jnp.int32), b.astype(jnp.int32), lut.astype(jnp.int32))

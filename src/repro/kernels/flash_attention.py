"""Blocked online-softmax attention (forward) Pallas kernel.

Used by the serving path at long context (prefill_32k) where materializing
S×S logits is impossible; the pure-jnp blocked implementation
(models/attention.py) is the differentiable/compile-anywhere path and this
kernel is the TPU hot path.  Supports causal masking and GQA (the q-head →
kv-head mapping happens in ops.py by reshaping to per-group batches).

Grid: (batch*heads, n_q_blocks, n_kv_blocks); running max/denominator and
the f32 accumulator tile live in VMEM scratch, revisited across the kv grid
dimension (standard flash pattern).  Block sizes default to MXU-aligned 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_i, l_i,
                           *, scale: float, causal: bool,
                           bq: int, bkv: int, n_kv: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_i[...] = jnp.full_like(m_i, NEG_INF)
        l_i[...] = jnp.zeros_like(l_i)

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale       # (BQ, D)
        k = k_ref[0].astype(jnp.float32)               # (BKV, D)
        v = v_ref[0].astype(jnp.float32)               # (BKV, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BKV)
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            k_pos = kb * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_i[...], s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i[...] - m_new)
        l_i[...] = l_i[...] * alpha + p.sum(axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_i[...] = m_new

    if causal:
        # skip fully-masked kv blocks (they are still visited by the grid;
        # the predicate saves the FLOPs/VMEM traffic)
        pl.when(kb * bkv <= qb * bq + bq - 1)(compute)
    else:
        compute()

    @pl.when(kb == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_i[...], 1e-30)
        o_ref[0] = (acc[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D) — heads pre-folded into batch."""
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    n_kv = Skv // bkv
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(flash_attention_kernel, scale=scale,
                               causal=causal, bq=bq, bkv=bkv, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(BH, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Fused CGP simulation + error-metric Pallas kernel (DESIGN.md §2).

THE paper hot loop: exhaustive bit-parallel candidate evaluation.  The TPU
formulation keeps the whole wire plane for a block of the input cube in VMEM
scratch and walks the netlist once with branch-free truth-table merges; the
same pass unpacks integer outputs and accumulates every error-metric partial
(Eq. 1-7 numerators) plus per-gate popcounts (for the activity power model) —
so a candidate costs exactly one HBM read of its input-plane block and O(10)
scalars of HBM write-back.

Two evaluation-grid LAYOUTS share one kernel body (DESIGN.md §7; the Pallas
grid runs sequentially with the LAST dimension innermost):

* ``layout="genome_major"`` — grid ``(R, W // bw)``: the GENOME axis is grid
  dimension 0 (one sweep-chunk of ``runs × λ`` candidates per dispatch,
  ``core.sweep``/``core.evolve`` flatten the population into it) and the
  input-cube block axis is dimension 1.  Outputs use the standard Pallas
  revisiting-accumulator pattern per genome: every cube block of genome ``r``
  maps to output row ``r``, initialized at block 0.  The cube axis must be
  INNERMOST for that pattern (an accumulator row's visits have to be
  consecutive grid steps), which means each genome streams the input cube
  from HBM once — same per-candidate traffic as the paper's formulation;
  what the fused grid removes is the per-genome dispatch/trace overhead.
* ``layout="cube_major"`` — the transposed grid ``(W // bw, R)``: the cube
  block axis is OUTER and the genome axis inner, so one cube block is loaded
  once and reused across the whole (chunk × λ) population before the next
  block streams in — per-dispatch HBM cube traffic drops from R reads of the
  cube to ONE (the input-plane/golden index maps ignore the inner genome
  index, so the pipeliner skips the re-fetch between consecutive steps).
  The revisiting-accumulator pattern no longer applies (a genome's visits
  are W//bw grid steps apart), so the per-genome accumulators live in
  explicitly-allocated ``(Rp, ·)`` VMEM scratch — zeroed at grid step
  (0, 0), accumulated row-wise every step, and flushed to the ``(R, ·)``
  output refs only on a genome's LAST cube step (§7.2 flush semantics).

Both layouts accumulate each genome's cube blocks in the same ascending
order, so their outputs are bit-identical (including the float32 ``rel_sum``
row) — layout is a pure execution knob, picked per (width, R, backend) by
``kernels.tune`` when callers pass ``layout="auto"``.  Input-space sharding
composes with the fused grid in either layout through
``cgp_sim_metrics_batched_sharded`` (per-genome accumulators psum/pmax
across the mesh axis — DESIGN.md §6).

All output refs are ≥2D ``(1, cols)`` blocks of ``(R, cols)`` arrays and the
golden values are blocked as ``(1, bw*32)`` rows (lane-dim multiple of 128 for
``bw ≥ 4``) so the kernel lowers through Mosaic — 1D refs and 1D iota are not
TPU-lowerable.  The genome axis is padded to a multiple of ``r_tile``
(default 8, one float32 sublane) so the ``(R, ·)`` accumulators stay
sublane-aligned; padded rows recompute the last genome and are sliced off.

VMEM budget at the paper scale (8x8 multiplier, 400 nodes, block=512 words):
  wires scratch (416, 512) int32 ≈ 0.85 MB; in-planes block 32 KB; golden
  block 64 KB; per-genome blocks: nodes 4.8 KB + accumulator rows < 2 KB —
  the genome grid axis adds only the nodes/outs/accumulator rows (the wire
  scratch is reused across ``r``), so the fused (runs × λ) grid stays at
  ~1 MB total, comfortably inside the ~16 MB/core budget, and the block
  shape keeps the lane dimension at 512 (mod-128 aligned).  The cube-major
  layout additionally holds ALL Rp accumulator rows in scratch:
  ``Rp × (N_SUMS + 1 + n_bins + n_n) × 4 B`` ≈ 1.7 KB/genome at 400 nodes —
  a chunk×λ population of 256 adds ~0.43 MB, and the layout stays inside
  the VMEM budget up to Rp ≈ 8k genomes per dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import gates

# sums vector layout (float32): exact split accumulation, see core.metrics.
# SQ_SUM/REL_SQ are appended second-moment rows (float32, variance estimators
# for the sampled-eval confidence intervals, DESIGN.md §9) — appended LAST so
# the historic row indices (and hence the exhaustive-path bit patterns of
# every pre-existing row) are unchanged.
ABS_HI, ABS_LO, ERR_CNT, REL_SUM, POS_HI, POS_LO, NEG_HI, NEG_LO, \
    ACC0_BAD, COUNT, SQ_SUM, REL_SQ = range(12)
N_SUMS = 12


def _gate_eval(func: jax.Array, a: jax.Array, b: jax.Array,
               tt_packed: int = gates.TT_PACKED) -> jax.Array:
    """Branch-free packed gate eval via a packed truth-table scalar.

    ``tt_packed`` holds up to eight 4-bit truth tables (bit ``k`` of table
    ``f`` = output for inputs with ``a + 2b = k``); ``func`` selects one.
    """
    tt = jax.lax.shift_right_logical(
        jnp.uint32(tt_packed), (4 * func).astype(jnp.uint32))
    tt = (tt & jnp.uint32(0xF)).astype(jnp.int32)
    na, nb = ~a, ~b
    m0, m1, m2, m3 = na & nb, a & nb, na & b, a & b
    s = lambda k: -((tt >> k) & 1)
    return (m0 & s(0)) | (m1 & s(1)) | (m2 & s(2)) | (m3 & s(3))


def _split_sum(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Exact block sums of byte-split magnitudes (see core.metrics)."""
    hi = (v >> 8).astype(jnp.float32).sum()
    lo = (v & 0xFF).astype(jnp.float32).sum()
    return hi, lo


def _sim_block_partials(nodes_ref, outs_ref, planes_ref, golden_ref, wires,
                        *, n_i: int, n_n: int, n_o: int, gauss_sigma: float,
                        n_gauss_side: int, n_bins: int):
    """One genome × one cube block: netlist walk + fused metric partials.

    Shared by both layout kernels — the layouts differ only in grid order
    and in WHERE the partials accumulate (output refs vs VMEM scratch).
    Returns ``(upd (N_SUMS,) f32, wce (scalar i32), hist (n_bins,) f32,
    pops (n_n,) f32)`` for this block.
    """
    bw = planes_ref.shape[1]

    # --- phase 1: netlist walk over the VMEM wire plane -------------------
    wires[0:n_i, :] = planes_ref[...]

    def node_step(k, _):
        # row 0: the genome axis is blocked to (1, ...) per grid step.  The
        # leading index must be a jnp scalar — interpret-mode discharge of a
        # mixed static/dynamic pl.load rejects raw Python ints.
        node = pl.load(nodes_ref, (jnp.int32(0), k, slice(None)))  # (3,) i32
        a = pl.load(wires, (node[0], slice(None)))
        b = pl.load(wires, (node[1], slice(None)))
        out = _gate_eval(node[2], a, b)
        pl.store(wires, (n_i + k, slice(None)), out)
        return 0

    jax.lax.fori_loop(0, n_n, node_step, 0)

    # per-gate popcounts for the activity power model
    gate_planes = wires[n_i:n_i + n_n, :]
    pops = jax.lax.population_count(
        gate_planes.view(jnp.uint32)).astype(jnp.float32).sum(axis=1)

    # --- phase 2: unpack outputs, fuse metric partials ---------------------
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bw, 32), 1)
    vals = jnp.zeros((bw, 32), jnp.int32)
    for o in range(n_o):  # static unroll: n_o is small (<= 2*width)
        plane = pl.load(wires, (outs_ref[0, o], slice(None)))  # (bw,)
        bits = (plane[:, None] >> lanes) & 1
        vals += bits << o

    g = golden_ref[...].reshape(bw, 32)
    diff = g - vals
    ad = jnp.abs(diff)
    nz = diff != 0

    abs_hi, abs_lo = _split_sum(ad)
    pos_hi, pos_lo = _split_sum(jnp.maximum(diff, 0))
    neg_hi, neg_lo = _split_sum(jnp.maximum(-diff, 0))
    upd = jnp.zeros((N_SUMS,), jnp.float32)
    upd = upd.at[ABS_HI].set(abs_hi).at[ABS_LO].set(abs_lo)
    upd = upd.at[POS_HI].set(pos_hi).at[POS_LO].set(pos_lo)
    upd = upd.at[NEG_HI].set(neg_hi).at[NEG_LO].set(neg_lo)
    adf = ad.astype(jnp.float32)
    relf = adf / jnp.maximum(g, 1).astype(jnp.float32)
    upd = upd.at[ERR_CNT].set(nz.astype(jnp.float32).sum())
    upd = upd.at[REL_SUM].set(relf.sum())
    upd = upd.at[ACC0_BAD].set(
        ((g == 0) & (vals != 0)).astype(jnp.float32).sum())
    upd = upd.at[COUNT].set(float(32) * bw)
    upd = upd.at[SQ_SUM].set((adf * adf).sum())
    upd = upd.at[REL_SQ].set((relf * relf).sum())

    # σ-wide histogram bins over ±n_side·σ (+2 tails); scatter-free: static
    # per-bin masked reductions (TPU-friendly, n_bins ~ 10)
    e0 = -float(n_gauss_side) * gauss_sigma
    idx = jnp.clip(
        jnp.floor((diff.astype(jnp.float32) - e0) / gauss_sigma).astype(jnp.int32) + 1,
        0, n_bins - 1)
    hist_upd = jnp.zeros((n_bins,), jnp.float32)
    for b in range(n_bins):  # static unroll
        hist_upd = hist_upd.at[b].set(((idx == b) & nz).astype(jnp.float32).sum())

    return upd, ad.max(), hist_upd, pops


def cgp_sim_kernel(nodes_ref, outs_ref, planes_ref, golden_ref,
                   sums_ref, wce_ref, hist_ref, pops_ref, wires,
                   *, n_i: int, n_n: int, n_o: int,
                   gauss_sigma: float, n_gauss_side: int, n_bins: int):
    """Genome-major (genome r, cube block w) grid step: the cube axis is
    innermost, so the ``(1, ·)`` output blocks are revisiting accumulators —
    initialized at a genome's block 0 and accumulated in place."""
    blk = pl.program_id(1)

    @pl.when(blk == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        wce_ref[...] = jnp.zeros_like(wce_ref)
        hist_ref[...] = jnp.zeros_like(hist_ref)
        pops_ref[...] = jnp.zeros_like(pops_ref)

    upd, wce, hist_upd, pops = _sim_block_partials(
        nodes_ref, outs_ref, planes_ref, golden_ref, wires, n_i=n_i, n_n=n_n,
        n_o=n_o, gauss_sigma=gauss_sigma, n_gauss_side=n_gauss_side,
        n_bins=n_bins)

    pops_ref[...] += pops[None, :]
    sums_ref[...] += upd[None, :]
    wce_ref[0, 0] = jnp.maximum(wce_ref[0, 0], wce)
    hist_ref[...] += hist_upd[None, :]


def cgp_sim_kernel_cube_major(nodes_ref, outs_ref, planes_ref, golden_ref,
                              sums_ref, wce_ref, hist_ref, pops_ref,
                              wires, sums_acc, wce_acc, hist_acc, pops_acc,
                              *, n_i: int, n_n: int, n_o: int,
                              gauss_sigma: float, n_gauss_side: int,
                              n_bins: int):
    """Cube-major (cube block w, genome r) grid step (DESIGN.md §7.2).

    The genome axis is innermost, so one cube block stays resident while
    every genome consumes it — but a genome's visits are now W//bw grid
    steps apart, which breaks the revisiting-accumulator pattern on the
    output refs.  Per-genome accumulators therefore live in ``(Rp, ·)``
    VMEM scratch: zeroed once at grid step (0, 0), accumulated at row ``r``
    every step, and flushed to the ``(1, ·)`` output block only on the last
    cube step.  (Output blocks written back before the flush step carry
    whatever the ref held — harmless: each output row's LAST write-back is
    its flush, which overwrites them.)
    """
    blk, r = pl.program_id(0), pl.program_id(1)

    @pl.when(jnp.logical_and(blk == 0, r == 0))
    def _init():
        sums_acc[...] = jnp.zeros_like(sums_acc)
        wce_acc[...] = jnp.zeros_like(wce_acc)
        hist_acc[...] = jnp.zeros_like(hist_acc)
        pops_acc[...] = jnp.zeros_like(pops_acc)

    upd, wce, hist_upd, pops = _sim_block_partials(
        nodes_ref, outs_ref, planes_ref, golden_ref, wires, n_i=n_i, n_n=n_n,
        n_o=n_o, gauss_sigma=gauss_sigma, n_gauss_side=n_gauss_side,
        n_bins=n_bins)

    row = (pl.ds(r, 1), slice(None))
    # same per-genome accumulation order over cube blocks as genome-major
    # (w ascending), so the float32 sums are bit-identical across layouts
    pl.store(pops_acc, row, pl.load(pops_acc, row) + pops[None, :])
    pl.store(sums_acc, row, pl.load(sums_acc, row) + upd[None, :])
    pl.store(wce_acc, row, jnp.maximum(pl.load(wce_acc, row),
                                       wce[None, None]))
    pl.store(hist_acc, row, pl.load(hist_acc, row) + hist_upd[None, :])

    @pl.when(blk == pl.num_programs(0) - 1)
    def _flush():
        sums_ref[...] = pl.load(sums_acc, row)
        wce_ref[...] = pl.load(wce_acc, row)
        hist_ref[...] = pl.load(hist_acc, row)
        pops_ref[...] = pl.load(pops_acc, row)


@functools.partial(
    jax.jit,
    static_argnames=("n_i", "n_n", "n_o", "gauss_sigma", "n_gauss_side",
                     "block_words", "r_tile", "layout", "interpret"))
def cgp_sim_metrics_batched(nodes: jax.Array, outs: jax.Array,
                            in_planes: jax.Array, golden_vals: jax.Array,
                            *, n_i: int, n_n: int, n_o: int,
                            gauss_sigma: float = 256.0, n_gauss_side: int = 4,
                            block_words: int = 512, r_tile: int = 8,
                            layout: str = "genome_major",
                            interpret: bool = True):
    """Fused (runs × λ) pallas_call: ONE dispatch for R stacked genomes.

    Args:
      nodes: (R, n_n, 3) int32 stacked genomes; outs: (R, n_o) int32.
      in_planes: (n_i, W) int32 — shared across the genome axis.
      golden_vals: (W*32,) int32 — shared across the genome axis.
      r_tile: sublane-alignment pad of the genome axis; R is padded up to a
        multiple with copies of the last genome, sliced off on return, so
        ragged R (e.g. a non-multiple sweep-chunk tail) is transparent.
      layout: evaluation-grid order (DESIGN.md §7).  ``"genome_major"`` puts
        the genome axis on grid dim 0 (cube innermost, output refs are
        revisiting accumulators); ``"cube_major"`` transposes the grid (cube
        outer, genomes inner, accumulators in flushed VMEM scratch) so one
        cube block is reused across the whole population.  Outputs are
        bit-identical across layouts; resolve ``"auto"`` upstream
        (``kernels.tune`` / ``ops.cgp_eval_batched``) — this function only
        accepts the two concrete spellings.
    Returns per-genome accumulators
      (sums (R, N_SUMS) f32, wce (R, 1) i32, hist (R, n_bins) f32,
       pops (R, n_n) f32).
    """
    if layout not in ("genome_major", "cube_major"):
        raise ValueError(
            f"layout must be 'genome_major' or 'cube_major', got {layout!r} "
            "(resolve 'auto' via kernels.tune before the kernel call)")
    R = nodes.shape[0]
    r_pad = (-R) % r_tile
    if r_pad:
        nodes = jnp.concatenate(
            [nodes, jnp.broadcast_to(nodes[-1:], (r_pad, n_n, 3))])
        outs = jnp.concatenate(
            [outs, jnp.broadcast_to(outs[-1:], (r_pad, n_o))])
    Rp = R + r_pad
    W = in_planes.shape[1]
    bw = min(block_words, W)
    assert W % bw == 0, (W, bw)
    n_bins = 2 * n_gauss_side + 2
    n_wires = n_i + n_n
    golden_blocks = golden_vals.reshape(W // bw, bw * 32)

    out_shapes = (
        jax.ShapeDtypeStruct((Rp, N_SUMS), jnp.float32),
        jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
        jax.ShapeDtypeStruct((Rp, n_bins), jnp.float32),
        jax.ShapeDtypeStruct((Rp, n_n), jnp.float32),
    )
    scratch_shapes = [pltpu.VMEM((n_wires, bw), jnp.int32)]  # wire plane
    if layout == "genome_major":
        kernel = functools.partial(
            cgp_sim_kernel, n_i=n_i, n_n=n_n, n_o=n_o,
            gauss_sigma=gauss_sigma, n_gauss_side=n_gauss_side, n_bins=n_bins)
        grid = (Rp, W // bw)
        genome_blk = lambda r, w: (r, 0)
        nodes_blk = lambda r, w: (r, 0, 0)
        planes_blk = lambda r, w: (0, w)
        golden_blk = lambda r, w: (w, 0)
    else:  # cube_major: transposed grid, accumulators in VMEM scratch
        kernel = functools.partial(
            cgp_sim_kernel_cube_major, n_i=n_i, n_n=n_n, n_o=n_o,
            gauss_sigma=gauss_sigma, n_gauss_side=n_gauss_side, n_bins=n_bins)
        grid = (W // bw, Rp)
        genome_blk = lambda w, r: (r, 0)
        nodes_blk = lambda w, r: (r, 0, 0)
        planes_blk = lambda w, r: (0, w)
        golden_blk = lambda w, r: (w, 0)
        scratch_shapes += [
            pltpu.VMEM((Rp, N_SUMS), jnp.float32),   # sums_acc
            pltpu.VMEM((Rp, 1), jnp.int32),          # wce_acc
            pltpu.VMEM((Rp, n_bins), jnp.float32),   # hist_acc
            pltpu.VMEM((Rp, n_n), jnp.float32),      # pops_acc
        ]
    acc_spec = lambda cols: pl.BlockSpec((1, cols), genome_blk)
    sums, wce, hist, pops = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_n, 3), nodes_blk),   # genome nodes
            pl.BlockSpec((1, n_o), genome_blk),     # genome outs
            pl.BlockSpec((n_i, bw), planes_blk),    # planes blk
            pl.BlockSpec((1, bw * 32), golden_blk),  # golden blk
        ],
        out_specs=(acc_spec(N_SUMS), acc_spec(1), acc_spec(n_bins),
                   acc_spec(n_n)),
        out_shape=out_shapes,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(nodes, outs, in_planes, golden_blocks)
    if r_pad:
        sums, wce, hist, pops = sums[:R], wce[:R], hist[:R], pops[:R]
    return sums, wce, hist, pops


def cgp_sim_metrics_batched_sharded(nodes: jax.Array, outs: jax.Array,
                                    in_planes: jax.Array,
                                    golden_vals: jax.Array, *,
                                    axis_name: str, n_i: int, n_n: int,
                                    n_o: int, gauss_sigma: float = 256.0,
                                    n_gauss_side: int = 4,
                                    block_words: int = 512, r_tile: int = 8,
                                    layout: str = "genome_major",
                                    interpret: bool = True):
    """Cube-shard variant of the fused batched kernel (DESIGN.md §6).

    Runs under input-space sharding (``shard_map`` with the cube's word axis
    split over ``axis_name``, conventionally the ``model`` mesh axis): every
    shard dispatches the SAME (runs × λ) Pallas grid on its local
    ``in_planes``/``golden_vals`` slice, then the per-genome accumulators
    combine across the axis — sums/histogram/popcounts (and the count row
    inside ``sums``) psum, the worst-case-error row pmax.  The psum contract
    stays exact for the integer-valued accumulators: every per-shard split
    sum is an integer < 2^24, so float32 psum is associative on them and the
    combined partials are bit-identical to the unsharded kernel's
    (``rel_sum`` alone is genuinely floating-point and only
    reassociation-close).

    This is what lets a pod's whole (chunk × λ) population fuse into one
    dispatch per generation even when the cube is sharded —
    ``evolve._eval_pop_pallas`` previously had to fall back to a vmap of
    per-genome kernels whenever ``axis_name`` was set.

    Same signature/returns as ``cgp_sim_metrics_batched`` plus ``axis_name``;
    ``in_planes`` is ``(n_i, W_local)`` and ``golden_vals`` ``(W_local*32,)``
    — this shard's word slice.  Must be called inside a context where
    ``axis_name`` is bound (it is not independently jit-able).
    """
    sums, wce, hist, pops = cgp_sim_metrics_batched(
        nodes, outs, in_planes, golden_vals, n_i=n_i, n_n=n_n, n_o=n_o,
        gauss_sigma=gauss_sigma, n_gauss_side=n_gauss_side,
        block_words=block_words, r_tile=r_tile, layout=layout,
        interpret=interpret)
    return (jax.lax.psum(sums, axis_name), jax.lax.pmax(wce, axis_name),
            jax.lax.psum(hist, axis_name), jax.lax.psum(pops, axis_name))


@functools.partial(
    jax.jit,
    static_argnames=("n_i", "n_n", "n_o", "gauss_sigma", "n_gauss_side",
                     "block_words", "interpret"))
def cgp_sim_metrics(nodes: jax.Array, outs: jax.Array, in_planes: jax.Array,
                    golden_vals: jax.Array, *, n_i: int, n_n: int, n_o: int,
                    gauss_sigma: float = 256.0, n_gauss_side: int = 4,
                    block_words: int = 512, interpret: bool = True):
    """Per-genome wrapper.  Returns (sums(N_SUMS,), wce(1,), hist, pops(n_n,)).

    in_planes: (n_i, W) int32; golden_vals: (W*32,) int32.  Delegates to the
    batched kernel with a singleton genome axis (``r_tile=1``: no pad rows),
    so there is exactly one kernel body to validate.
    """
    sums, wce, hist, pops = cgp_sim_metrics_batched(
        nodes[None], outs[None], in_planes, golden_vals,
        n_i=n_i, n_n=n_n, n_o=n_o, gauss_sigma=gauss_sigma,
        n_gauss_side=n_gauss_side, block_words=block_words,
        r_tile=1, interpret=interpret)
    return sums[0], wce[0], hist[0], pops[0]

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core import simulate
from repro.core.genome import CGPSpec, Genome


def cgp_eval_ref(genome: Genome, spec: CGPSpec, in_planes: jax.Array,
                 golden_vals: jax.Array, gauss_sigma: float
                 ) -> tuple[M.MetricPartials, jax.Array]:
    """Oracle for kernels.cgp_sim: (metric partials, per-gate popcounts)."""
    wires = simulate.simulate_planes(genome, spec, in_planes)
    cand_vals = simulate.unpack_values(wires[genome.outs])
    partials = M.error_partials(golden_vals, cand_vals, gauss_sigma,
                                n_bits=spec.n_o)
    pops = jax.lax.population_count(
        wires[spec.n_i:].view(jnp.uint32)).astype(jnp.float32).sum(axis=-1)
    return partials, pops


def lut_matmul_ref(a: jax.Array, b: jax.Array, lut: jax.Array) -> jax.Array:
    """C[m,n] = Σ_k LUT[a[m,k], b[k,n]] — direct take-based oracle."""
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    idx = a[:, :, None] * 256 + b[None, :, :]          # (M, K, N)
    prods = jnp.take(lut.reshape(-1).astype(jnp.int32), idx, axis=0)
    return prods.sum(axis=1)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """Naive softmax attention oracle. q: (BH, Sq, D), k/v: (BH, Skv, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

"""Kernel-layout tuning subsystem (DESIGN.md §7.3).

The fused CGP-evaluation kernel has execution knobs that change throughput
but never results: the evaluation-grid ``layout`` (genome-major vs the
transposed cube-major grid of ``cgp_sim``), the cube ``block_words`` and the
genome-axis pad ``r_tile``.  Which combination wins depends on the problem
shape and the backend — cube-block reuse only pays where HBM traffic is real
(TPU), interpret mode pays per pad row, small cubes fit in one block anyway.
This module owns that decision:

  * ``KernelVariant`` — one (layout, block_words, r_tile) point; the
    ``default_variants`` registry enumerates the candidates for a problem
    shape (both layouts × the block sizes that divide the cube).
  * ``autotune`` — measured pass: dispatches the real batched kernel on a
    synthetic population for every variant, times it through the same
    machinery ``benchmarks/kernel_micro.py`` uses (pass its timer as
    ``time_fn``; the built-in default is equivalent), and persists the
    winner into the JSON tuning table.
  * the tuning table — one JSON file (``REPRO_TUNE_TABLE`` env var, default
    ``experiments/tuning/kernel_layout.json``), ``entries`` keyed by
    ``w{width}_r{R}_{backend}`` so interpret-mode measurements can never
    shadow TPU ones.  Schema in DESIGN.md §7.3.
  * ``resolve_variant``/``resolve_layout`` — the ``layout="auto"`` path used
    by ``kernels.ops.cgp_eval_batched``: exact (width, R, backend) hit,
    else the nearest-R entry of the same (width, backend), else the
    conservative default (genome-major — the longest-validated layout).

Tuning entries are advisory, never load-bearing: both layouts are
bit-identical (differentially tested), so a stale or foreign table can cost
throughput but not correctness.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Callable, Sequence

LAYOUTS = ("genome_major", "cube_major")
DEFAULT_LAYOUT = "genome_major"
TABLE_ENV = "REPRO_TUNE_TABLE"
TABLE_VERSION = 1

_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DEFAULT_TABLE = os.path.join(_ROOT, "experiments", "tuning",
                             "kernel_layout.json")

# candidate cube block sizes (words); clipped to the cube width per problem
BLOCK_CANDIDATES = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class KernelVariant:
    """One point of the kernel execution space (results-invariant knobs)."""
    layout: str = DEFAULT_LAYOUT
    block_words: int = 512
    r_tile: int = 8

    def key(self) -> str:
        return f"{self.layout}/bw{self.block_words}/rt{self.r_tile}"


def table_path() -> str:
    return os.environ.get(TABLE_ENV) or DEFAULT_TABLE


def table_key(width: int, R: int, backend: str) -> str:
    return f"w{width}_r{R}_{backend}"


def backend_key(interpret: bool) -> str:
    """Tuning-table backend tag: measurements taken in interpret mode are
    meaningless for the compiled kernel and must never shadow it."""
    if interpret:
        return "interpret"
    import jax
    return jax.default_backend()


# path -> (stat token, parsed table or None-for-unparseable).  The token is
# (st_mtime_ns, st_size, st_ino) rather than a bare mtime: a same-second
# rewrite is invisible to 1s-granularity mtimes on some filesystems, but the
# atomic-rename writes used here always change the inode (and usually the
# size), so the token catches it.  Parse failures are cached under the same
# token (value None) so a corrupt table isn't re-read and re-parsed on every
# trace's ``resolve_variant`` call.
_TABLE_CACHE: dict[str, tuple[tuple[int, int, int], dict | None]] = {}


def _stat_token(path: str) -> tuple[int, int, int] | None:
    try:
        st = os.stat(path)
    except OSError:
        return None
    return (st.st_mtime_ns, st.st_size, st.st_ino)


def load_table(path: str | None = None) -> dict:
    """Read the tuning table ({} if absent/invalid).  Cached by stat token so
    the per-trace ``resolve_variant`` calls don't re-read the file."""
    path = path or table_path()
    token = _stat_token(path)
    if token is None:
        return {}
    cached = _TABLE_CACHE.get(path)
    if cached is not None and cached[0] == token:
        return cached[1] if cached[1] is not None else {}
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        _TABLE_CACHE[path] = (token, None)  # negative-cache the parse failure
        return {}
    if not isinstance(table, dict) or "entries" not in table:
        _TABLE_CACHE[path] = (token, None)
        return {}
    _TABLE_CACHE[path] = (token, table)
    return table


def save_entry(width: int, R: int, backend: str, entry: dict,
               path: str | None = None) -> dict:
    """Merge one winner entry into the table (atomic rename write)."""
    from repro.checkpoint import store
    path = path or table_path()
    table = dict(load_table(path)) or {"version": TABLE_VERSION,
                                       "entries": {}}
    entries = dict(table.get("entries", {}))
    entries[table_key(width, R, backend)] = entry
    table["entries"] = entries
    table["version"] = TABLE_VERSION
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    store.atomic_write_json(path, table)
    _TABLE_CACHE.pop(path, None)
    return table


def default_variants(n_words: int, interpret: bool,
                     r_tiles: Sequence[int] | None = None
                     ) -> list[KernelVariant]:
    """Registry of candidate variants for a cube of ``n_words`` words.

    Both layouts × every candidate block size that divides the cube (the
    kernel requires ``W % bw == 0``); interpret mode pays every pad row as a
    recomputed evaluation, so its registry pins ``r_tile=1`` while compiled
    candidates use the sublane-aligned 8.
    """
    if r_tiles is None:
        r_tiles = (1,) if interpret else (8,)
    blocks = sorted({min(b, n_words) for b in BLOCK_CANDIDATES
                     if n_words % min(b, n_words) == 0})
    return [KernelVariant(layout=layout, block_words=bw, r_tile=rt)
            for layout in LAYOUTS for bw in blocks for rt in r_tiles]


def resolve_variant(width: int, R: int, backend: str,
                    path: str | None = None,
                    default: KernelVariant | None = None) -> KernelVariant:
    """The ``layout="auto"`` resolution path (exact → nearest-R → default).

    Nearest-R matching (log-distance, same width+backend) makes a sparse
    table useful: a sweep's chunk×λ population size rarely equals a tuned R
    exactly, but the winning layout is stable across nearby R.  ``default``
    is returned on a full miss (callers pass their interpret-aware
    execution defaults; the bare ``KernelVariant()`` otherwise).
    """
    entries = load_table(path).get("entries", {})
    hit = entries.get(table_key(width, R, backend))
    if hit is None:
        suffix = f"_{backend}"
        prefix = f"w{width}_r"
        best = None
        for key, entry in entries.items():
            if not (key.startswith(prefix) and key.endswith(suffix)):
                continue
            try:
                r_ent = int(key[len(prefix):-len(suffix)])
            except ValueError:
                continue
            dist = abs(math.log(max(r_ent, 1)) - math.log(max(R, 1)))
            if best is None or dist < best[0]:
                best = (dist, entry)
        hit = best[1] if best is not None else None
    if hit is None:
        return default if default is not None else KernelVariant()
    return KernelVariant(layout=hit.get("layout", DEFAULT_LAYOUT),
                         block_words=int(hit.get("block_words", 512)),
                         r_tile=int(hit.get("r_tile", 8)))


def resolve_layout(width: int, R: int, backend: str,
                   path: str | None = None) -> str:
    return resolve_variant(width, R, backend, path).layout


def _measure(fn: Callable[[], object], reps: int) -> float:
    """Default timer — same protocol as ``benchmarks.kernel_micro._time``
    (compile + warm call, then averaged timed reps, block_until_ready)."""
    import jax
    fn()
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def autotune(width: int, R: int, *, kind: str = "mul", n_n: int = 400,
             gauss_sigma: float = 256.0, reps: int = 3,
             variants: Sequence[KernelVariant] | None = None,
             interpret: bool | None = None, path: str | None = None,
             time_fn: Callable[[Callable[[], object], int], float] | None
             = None) -> dict:
    """Measure every registry variant on a synthetic R-genome population and
    persist the winner for this (width, R, backend) into the tuning table.

    ``time_fn(fn, reps) -> seconds`` lets callers supply their own timing
    machinery (``benchmarks/kernel_micro.py --tune`` passes its ``_time``);
    the default is equivalent.  Returns the written entry, which includes
    the full per-variant measurement for the bench trajectory.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import golden as G
    from repro.core import simulate as S
    from repro.core.genome import CGPSpec, random_genome
    from repro.kernels import cgp_sim

    if interpret is None:
        from repro.kernels import ops
        interpret = ops.default_interpret()
    backend = backend_key(interpret)
    spec = CGPSpec(n_i=2 * width, n_o=2 * width, n_n=n_n)
    planes = S.input_planes(spec.n_i)
    gvals = jnp.asarray(G.golden_values(width, kind))
    genomes = jax.vmap(lambda k: random_genome(k, spec))(
        jax.random.split(jax.random.PRNGKey(0), R))
    if variants is None:
        variants = default_variants(planes.shape[1], interpret)
    if time_fn is None:
        time_fn = _measure

    timings: dict[str, float] = {}
    for v in variants:
        def dispatch(v=v):
            return cgp_sim.cgp_sim_metrics_batched(
                genomes.nodes, genomes.outs, planes, gvals, n_i=spec.n_i,
                n_n=spec.n_n, n_o=spec.n_o, gauss_sigma=gauss_sigma,
                layout=v.layout, block_words=v.block_words, r_tile=v.r_tile,
                interpret=interpret)
        timings[v.key()] = time_fn(dispatch, reps)

    winner = min(variants, key=lambda v: timings[v.key()])
    entry = {
        "layout": winner.layout,
        "block_words": winner.block_words,
        "r_tile": winner.r_tile,
        "width": width, "R": R, "backend": backend,
        "n_n": n_n, "kind": kind, "reps": reps,
        "seconds": {k: round(t, 6) for k, t in timings.items()},
    }
    save_entry(width, R, backend, entry, path)
    return entry

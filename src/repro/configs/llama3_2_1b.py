"""llama3.2-1b — small llama3 dense GQA [hf:meta-llama/Llama-3.2-1B]

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256, tie_embeddings=True, rope_theta=500000.0,
    period=(LayerSpec(kind="attn"),),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=256, vocab=512, tie_embeddings=True, rope_theta=500000.0)

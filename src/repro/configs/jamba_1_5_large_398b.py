"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2 [arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Period of 8 layers: attention at index 3, mamba elsewhere; MoE on
odd layer indices (16 experts, top-2), dense FFN on even.  Runs
long_500k (hybrid: 9 attention layers use a sequence-sharded cache
with flash-decoding merge; mamba layers are O(1) state).
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


_PERIOD = tuple(
    LayerSpec(kind=("attn" if i == 3 else "ssm"), moe=(i % 2 == 1))
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, tie_embeddings=False, rope_theta=10000.0,
    period=_PERIOD,
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, n_groups=1,
                  conv_width=4, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  capacity_factor=1.25),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adafactor"


def reduced() -> ModelConfig:
    period = tuple(
        LayerSpec(kind=("attn" if i == 3 else "ssm"), moe=(i % 2 == 1))
        for i in range(8))
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, tie_embeddings=False, period=period,
        ssm=SSMConfig(d_state=16, expand=2, headdim=16, chunk=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      capacity_factor=2.0))

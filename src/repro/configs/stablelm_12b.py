"""stablelm-12b — dense GQA transformer [hf:stabilityai/stablelm-2-12b]

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352 (head_dim 160).
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352, tie_embeddings=False, rope_theta=10000.0,
    period=(LayerSpec(kind="attn"),),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw8bit"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b-smoke", family="dense",
        n_layers=2, d_model=80, n_heads=4, n_kv_heads=2, head_dim=20,
        d_ff=192, vocab=512, tie_embeddings=False)

"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table) [arXiv:2501.kimi2]

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840;
MoE 384 experts top-8.  Deviation noted in DESIGN.md: the HF model
keeps layer 0 dense + a shared expert; we use a homogeneous all-MoE
stack so the layer scan stays period-1 (<1% of total params).
Factored optimizer + full remat are REQUIRED to fit (EXPERIMENTS.md).
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840, tie_embeddings=False, rope_theta=50000.0,
    period=(LayerSpec(kind="attn", moe=True),),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=256,
)

OPTIMIZER = "adafactor"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, head_dim=14,
        d_ff=32, vocab=512, tie_embeddings=False,
        period=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=2.0))

"""qwen3-moe-30b-a3b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B]

48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936;
MoE every layer, experts shard over the model axis (EP).
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=768, vocab=151936, tie_embeddings=False, rope_theta=10000.0,
    period=(LayerSpec(kind="attn", moe=True),),
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768,
                  capacity_factor=1.25),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    remat_policy="block_outputs",  # §Perf hillclimb B1
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw8bit"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32, vocab=512,
        tie_embeddings=False,
        period=(LayerSpec(kind="attn", moe=True),),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=2.0))

"""stablelm-1.6b — dense MHA transformer [hf:stabilityai/stablelm-2-1_6b]

24L d_model=2048 32H (kv=32, i.e. MHA) d_ff=5632 vocab=100352.
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100352, tie_embeddings=False, rope_theta=10000.0,
    period=(LayerSpec(kind="attn"),),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab=512, tie_embeddings=False)

"""Config system: model/shape/run configs + the architecture registry.

Every assigned architecture is a module ``configs/<id>.py`` exposing
``CONFIG`` (exact paper/HF shape), ``reduced()`` (CPU smoke variant) and the
four standard input shapes.  ``--arch <id>`` resolves through ``registry()``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period of a decoder stack."""
    kind: str = "attn"          # "attn" | "ssm"
    moe: bool = False           # FFN is a mixture-of-experts
    cross_attn: bool = False    # cross-attention to frontend embeddings
    has_ffn: bool = True        # mamba2-only stacks have no FFN


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    headdim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128            # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 768
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    act: str = "swiglu"
    tie_embeddings: bool = True
    period: tuple[LayerSpec, ...] = (LayerSpec(),)
    ssm: SSMConfig = SSMConfig()
    moe: MoEConfig = MoEConfig()
    # frontends (STUBS per task spec: input_specs provides embeddings/tokens)
    frontend: str = "none"      # none | vision | audio
    n_img_tokens: int = 0
    n_codebooks: int = 0
    # numerics / performance knobs
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    remat: bool = False
    # "all" = recompute everything (min memory);
    # "block_outputs" = save each mixer/FFN residual-stream output so the
    # backward recompute's collectives (TP/EP psums) dead-code away
    # (EXPERIMENTS.md §Perf hillclimb B)
    remat_policy: str = "all"
    loss_vocab_chunk: int = 0   # 0 = unchunked cross-entropy
    approx_matmul: bool = False  # evolved approximate-multiplier emulation
    scan_layers: bool = True
    # attention implementation: "blocked" (scan online-softmax, differentiable,
    # compiles on any backend) | "pallas" (TPU flash kernel) | "naive"
    attn_impl: str = "blocked"
    attn_block_q: int = 512
    attn_block_kv: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period {len(self.period)}")
        return self.n_layers // len(self.period)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def adtype(self):
        return jnp.dtype(self.act_dtype)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (see task brief: 4 per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # "train" | "prefill" | "decode"


# the four standard LM shape cells
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


ARCH_IDS = (
    "mamba2_1_3b", "phi4_mini_3_8b", "stablelm_1_6b", "stablelm_12b",
    "llama3_2_1b", "qwen3_moe_30b_a3b", "kimi_k2_1t_a32b",
    "jamba_1_5_large_398b", "llama3_2_vision_11b", "musicgen_large",
)

# pure full-attention archs skip long_500k (sub-quadratic required; DESIGN.md)
SUBQUADRATIC = ("mamba2_1_3b", "jamba_1_5_large_398b")


def get_arch(arch_id: str):
    """Import configs/<arch_id>.py and return its module."""
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{arch_id}")


def shapes_for(arch_id: str) -> tuple[ShapeConfig, ...]:
    if arch_id in SUBQUADRATIC:
        return ALL_SHAPES
    return tuple(s for s in ALL_SHAPES if s is not LONG_500K)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: int | None = None) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation — used by the dry-run lowering and smoke tests.
    """
    B = batch_override if batch_override is not None else shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    def token_spec(bs, sl):
        if cfg.frontend == "audio":
            return sds((bs, sl, cfg.n_codebooks), i32)
        return sds((bs, sl), i32)

    if shape.mode == "train":
        specs = {"tokens": token_spec(B, S), "targets": token_spec(B, S)}
    elif shape.mode == "prefill":
        specs = {"tokens": token_spec(B, S)}
    else:  # decode: one new token against a cache of S
        specs = {"tokens": token_spec(B, 1),
                 "pos": sds((B,), i32)}
    if cfg.frontend == "vision":
        specs["image_embeds"] = sds((B, cfg.n_img_tokens, cfg.d_model),
                                    jnp.dtype(cfg.act_dtype))
    return specs

"""llama-3.2-vision-11b — cross-attention image layers [hf:meta-llama/Llama-3.2-11B-Vision]

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
Period of 5: one gated cross-attention layer + four self-attention
layers (8 cross layers total).  The vision tower is a STUB per the
task spec: input_specs() provides precomputed patch embeddings
(B, 1600, d_model) consumed by the cross-attention K/V.
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


_PERIOD = (LayerSpec(kind="attn", cross_attn=True),) + \
    tuple(LayerSpec(kind="attn") for _ in range(4))

CONFIG = ModelConfig(
    name="llama3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, tie_embeddings=False, rope_theta=500000.0,
    period=_PERIOD, frontend="vision", n_img_tokens=1600,
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw8bit"


def reduced() -> ModelConfig:
    period = (LayerSpec(kind="attn", cross_attn=True),
              LayerSpec(kind="attn"))
    return ModelConfig(
        name="llama3.2-vision-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, tie_embeddings=False, period=period,
        frontend="vision", n_img_tokens=16)

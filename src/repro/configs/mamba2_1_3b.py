"""mamba2-1.3b — pure SSM (SSD / state-space duality) [arXiv:2405.21060]

48L d_model=2048, attention-free, d_ff=0, vocab=50280, ssm_state=128.
Runs ALL four shapes including long_500k (O(1) decode state).
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=0,
    vocab=50280, tie_embeddings=True,
    period=(LayerSpec(kind="ssm", has_ffn=False),),
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, n_groups=1,
                  conv_width=4, chunk=256),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw8bit"


def reduced() -> ModelConfig:
    """CPU smoke variant — same family, tiny dims."""
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0, vocab=256,
        tie_embeddings=True,
        period=(LayerSpec(kind="ssm", has_ffn=False),),
        ssm=SSMConfig(d_state=16, expand=2, headdim=16, chunk=16))

"""phi4-mini-3.8b — dense GQA transformer [arXiv:2412.08905]

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064; RoPE SwiGLU.
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=200064, tie_embeddings=True, rope_theta=10000.0,
    period=(LayerSpec(kind="attn"),),
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
    loss_vocab_chunk=512,
)

OPTIMIZER = "adamw"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=512, tie_embeddings=True)

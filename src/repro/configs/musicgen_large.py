"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284]

48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.
Audio frontend is a STUB per the task spec: inputs are 4 parallel
EnCodec codebook token streams (B, S, 4); embeddings are summed and
the LM head predicts all 4 codebooks (delay pattern handled by the
data layer).
"""
from repro.configs.base import (ModelConfig, LayerSpec, SSMConfig, MoEConfig)


CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, tie_embeddings=False, act="gelu",
    period=(LayerSpec(kind="attn"),),
    frontend="audio", n_codebooks=4,
    param_dtype="bfloat16", act_dtype="bfloat16", remat=True,
)

OPTIMIZER = "adamw"


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=64, tie_embeddings=False, act="gelu",
        frontend="audio", n_codebooks=4)

"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on the synthetic corpus, with checkpoints and auto-resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(The task brief's (b): an end-to-end train driver.  ~100M params is the
largest practical size for a few hundred CPU steps; pass --tiny for CI.)
"""
import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    import dataclasses
    import jax
    from repro.configs.base import ModelConfig
    from repro.launch.train import train
    from repro.configs import llama3_2_1b as arch

    if args.tiny:
        steps, batch, seq = min(args.steps, 30), 4, 64
        cfg_override = None  # use the arch's reduced() config
        out = train("llama3_2_1b", steps=steps, batch=batch, seq=seq,
                    reduced=True, ckpt_dir=args.ckpt_dir, ckpt_every=10)
    else:
        # ~100M-param llama-style config (d512 x 8L, 32k vocab)
        import repro.configs.llama3_2_1b as mod
        cfg_100m = ModelConfig(
            name="llama-100m", family="dense", n_layers=8, d_model=512,
            n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
            tie_embeddings=True, rope_theta=500000.0)
        old = mod.reduced
        mod.reduced = lambda: cfg_100m
        try:
            out = train("llama3_2_1b", steps=args.steps, batch=8, seq=256,
                        reduced=True, ckpt_dir=args.ckpt_dir,
                        ckpt_every=50, microbatches=2)
        finally:
            mod.reduced = old
    losses = out["losses"]
    print(f"\nloss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"({out['wall_s']:.0f}s total)")
    assert losses[-1] < losses[0], "loss did not decrease"
    print("training loss decreased — OK")


if __name__ == "__main__":
    main()

"""Quickstart: evolve an approximate 4x4 multiplier under COMBINED error
constraints (paper Eq. 9) and print its full characterization.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~1 minute on CPU.  This is the paper's core experiment in miniature:
start from the exact array multiplier, mutate under fitness
``power if (MAE<=1% ∧ ER<=60%) else ∞``, and report the trade-off.
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.search import SearchConfig, run_search


def main():
    cfg = SearchConfig(
        width=4,                      # 4x4 multiplier: 2^8 exhaustive inputs
        n_n=150,
        evolve=EvolveConfig(generations=4000, lam=8, seed=0),
    )
    constraint = ConstraintSpec(mae=1.0, er=60.0)   # the combined objective
    print(f"Evolving under: {constraint.describe()}")
    rec, res = run_search(cfg, constraint, seed=0)

    print(f"\nfeasible:        {rec.feasible}")
    print(f"relative power:  {rec.power_rel:.3f}  "
          f"(power reduction {100 * (1 - rec.power_rel):.1f}%)")
    for name, idx in (("MAE%", M.MAE), ("WCE%", M.WCE), ("ER%", M.ER),
                      ("MRE%", M.MRE), ("|AVG|%", M.AVG)):
        print(f"{name:8s} {rec.metrics[idx]:.4f}")
    print(f"ACC0 holds:      {bool(rec.metrics[M.ACC0])}")
    print(f"error mean/std:  {rec.error_mean:.2f} / {rec.error_std:.2f}")

    hist = np.asarray(res.hist_power_rel)
    feas = np.isfinite(np.asarray(res.hist_fit))
    print(f"\npower trajectory (every 500 gens): "
          f"{[round(float(h), 3) for h in hist[::500]]}")
    print(f"first feasible improvement at generation "
          f"{int(np.argmax(hist < 1.0)) if (hist < 1.0).any() else 'n/a'}")


if __name__ == "__main__":
    main()

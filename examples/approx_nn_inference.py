"""Deploy an evolved approximate multiplier inside an LM (paper ref. [4]'s
use case, the motivation for the ACC0 metric).

    python examples/approx_nn_inference.py --registry /path/to/registry

Consumes a fingerprinted circuit artifact from the registry a sweep exported
(``launch.evolve --export-artifacts`` / ``python -m repro.launch.export``,
DESIGN.md §12): the artifact's LUT is digest-verified and replayed from its
genome, then a small transformer runs with every projection matmul routed
through the emulated approximate arithmetic (models/quant.py), reporting the
model-level degradation (perplexity delta) vs exact fp32 and vs exact-int8.

Without ``--registry``/``--artifact`` the demo falls back to evolving a
fresh 8×8 multiplier inline (``--evolve``-equivalent; slower, and the
circuit is neither certified nor registered) so the example stays
self-contained.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def perplexity(params, toks, cfg):
    from repro.models import model as M
    loss = M.lm_loss(params, toks, toks, cfg)
    return float(jnp.exp(loss))


def evolve_inline():
    """Fallback: evolve an 8x8 multiplier here (short budget; use
    launch.evolve + the artifact registry for real runs)."""
    from repro.core.evolve import EvolveConfig
    from repro.core.fitness import ConstraintSpec
    from repro.core.genome import CGPSpec, Genome
    from repro.core.library import multiplier_lut
    from repro.core.search import SearchConfig, run_search
    scfg = SearchConfig(width=8, n_n=400,
                        evolve=EvolveConfig(generations=600, lam=8))
    con = ConstraintSpec(mae=0.1, er=95.0, acc0=True)
    print(f"evolving 8x8 multiplier under {con.describe()} ...")
    rec, _ = run_search(scfg, con, seed=0)
    print(f"  feasible={rec.feasible} power_rel={rec.power_rel:.3f} "
          f"mae={rec.metrics[0]:.4f}% er={rec.metrics[2]:.1f}%")
    genome = Genome(jnp.asarray(rec.genome_nodes),
                    jnp.asarray(rec.genome_outs))
    lut = multiplier_lut(genome, CGPSpec(16, 16, 400))
    return lut, rec.power_rel, con.describe()


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Model-level degradation study of an evolved "
                    "approximate multiplier (registry artifact or inline "
                    "evolution).")
    ap.add_argument("--artifact", default=None,
                    help="registry artifact .npz to deploy (digest-verified "
                         "+ genome-replayed before use)")
    ap.add_argument("--registry", default=None,
                    help="registry directory; the lowest-power feasible "
                         "artifact is selected")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args(argv)

    from repro.configs.base import ModelConfig
    from repro.models import model as M
    from repro.models import quant

    # 1. the deployment artifact: registry-verified, or evolved inline
    if args.artifact or args.registry:
        from repro.core.artifacts import resolve_artifact
        art = resolve_artifact(args.artifact or args.registry)
        lut, power_rel, constraint = art.lut, art.power_rel, art.constraint
        print(f"artifact {art.path}: {constraint} (seed {art.seed}, "
              f"power_rel={power_rel:.3f}, certified={art.certified}, "
              f"digest {art.digest[:12]}...)")
    else:
        lut, power_rel, constraint = evolve_inline()

    exact = np.arange(256)[:, None] * np.arange(256)[None, :]
    print(f"  LUT mean |err| = {np.abs(lut - exact).mean():.2f} "
          f"(of max product 65025)")

    # 2. model-level impact
    cfg = ModelConfig(name="toy", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=256)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (args.batch, args.seq_len), 0, cfg.vocab)

    ppl_fp = perplexity(params, toks, cfg)
    cfg_q = dataclasses.replace(cfg, approx_matmul=True)

    quant.set_multiplier_lut(None)           # exact int8 baseline
    ppl_int8 = perplexity(params, toks, cfg_q)
    quant.set_multiplier_lut(lut)            # evolved approximate circuit
    ppl_approx = perplexity(params, toks, cfg_q)
    quant.set_multiplier_lut(None)

    print(f"\nperplexity  fp32:        {ppl_fp:.4f}")
    print(f"perplexity  exact-int8:  {ppl_int8:.4f} "
          f"(quantization cost {100 * (ppl_int8 / ppl_fp - 1):+.2f}%)")
    print(f"perplexity  approx-mult: {ppl_approx:.4f} "
          f"(total cost {100 * (ppl_approx / ppl_fp - 1):+.2f}%)")
    print(f"\n=> the evolved circuit at {power_rel:.2f}x power adds "
          f"{100 * (ppl_approx / ppl_int8 - 1):+.2f}% perplexity over "
          f"exact int8 arithmetic")
    return {"ppl_fp32": ppl_fp, "ppl_int8": ppl_int8,
            "ppl_approx": ppl_approx, "power_rel": power_rel,
            "constraint": constraint}


if __name__ == "__main__":
    main()

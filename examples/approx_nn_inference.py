"""Deploy an evolved approximate multiplier inside an LM (paper ref. [4]'s
use case, the motivation for the ACC0 metric).

    PYTHONPATH=src python examples/approx_nn_inference.py

1. Evolves an 8x8 approximate multiplier under MAE+ER (+ACC0) constraints.
2. Builds its 256x256 product LUT (``core.library.multiplier_lut``) — on
   silicon this circuit replaces the MAC multipliers; here the LUT
   *emulates* it exactly.
3. Runs a small transformer with every projection matmul routed through the
   emulated approximate arithmetic (models/quant.py) and reports the
   model-level degradation (logit error / perplexity delta) vs exact fp32
   and vs exact-int8.
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.genome import CGPSpec
from repro.core.library import multiplier_lut
from repro.core.search import SearchConfig, run_search
from repro.models import model as M
from repro.models import quant


def perplexity(params, toks, cfg):
    loss = M.lm_loss(params, toks, toks, cfg)
    return float(jnp.exp(loss))


def main():
    # 1. evolve the circuit (short budget; use launch.evolve for real runs)
    scfg = SearchConfig(width=8, n_n=400,
                        evolve=EvolveConfig(generations=600, lam=8))
    con = ConstraintSpec(mae=0.1, er=95.0, acc0=True)
    print(f"evolving 8x8 multiplier under {con.describe()} ...")
    rec, _ = run_search(scfg, con, seed=0)
    print(f"  feasible={rec.feasible} power_rel={rec.power_rel:.3f} "
          f"mae={rec.metrics[0]:.4f}% er={rec.metrics[2]:.1f}%")

    # 2. deployment artifact
    from repro.core.library import record_to_genome
    genome = __import__("repro.core.genome", fromlist=["Genome"]).Genome(
        jnp.asarray(rec.genome_nodes), jnp.asarray(rec.genome_outs))
    lut = multiplier_lut(genome, CGPSpec(16, 16, 400))
    exact = np.arange(256)[:, None] * np.arange(256)[None, :]
    print(f"  LUT mean |err| = {np.abs(lut - exact).mean():.2f} "
          f"(of max product 65025)")

    # 3. model-level impact
    cfg = ModelConfig(name="toy", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab=256)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab)

    ppl_fp = perplexity(params, toks, cfg)
    cfg_q = dataclasses.replace(cfg, approx_matmul=True)

    quant.set_multiplier_lut(None)           # exact int8 baseline
    ppl_int8 = perplexity(params, toks, cfg_q)
    quant.set_multiplier_lut(lut)            # evolved approximate circuit
    ppl_approx = perplexity(params, toks, cfg_q)
    quant.set_multiplier_lut(None)

    print(f"\nperplexity  fp32:        {ppl_fp:.4f}")
    print(f"perplexity  exact-int8:  {ppl_int8:.4f} "
          f"(quantization cost {100 * (ppl_int8 / ppl_fp - 1):+.2f}%)")
    print(f"perplexity  approx-mult: {ppl_approx:.4f} "
          f"(total cost {100 * (ppl_approx / ppl_fp - 1):+.2f}%)")
    print(f"\n=> the evolved circuit at {rec.power_rel:.2f}x power adds "
          f"{100 * (ppl_approx / ppl_int8 - 1):+.2f}% perplexity over "
          f"exact int8 arithmetic")


if __name__ == "__main__":
    main()

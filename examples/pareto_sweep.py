"""Paper-style constraint sweep -> Pareto fronts (Fig. 14 in miniature).

    PYTHONPATH=src python examples/pareto_sweep.py [--width 6] [--gens 800]

Sweeps single-metric objectives (MAE, ER) against the combined ER+MAE
objective and prints the power/metric Pareto fronts, demonstrating the
paper's headline claim: the combination wins globally.
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import metrics as M
from repro.core.evolve import EvolveConfig
from repro.core.fitness import ConstraintSpec
from repro.core.pareto import pareto_points
from repro.core.search import SearchConfig
from repro.core.sweep import SweepConfig, run_sweep_batched


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--gens", type=int, default=1500)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--chunk-size", type=int, default=32)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="resume an interrupted sweep from here")
    ap.add_argument("--results-dir", default=None,
                    help="stream per-chunk result shards here (resumable; "
                         "histories spill to disk, host memory stays flat) "
                         "and read the fronts back through SweepResultReader")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"],
                    help="candidate evaluation: pure-jnp or the fused "
                         "(runs x lambda) Pallas kernel (interpret on CPU)")
    args = ap.parse_args()

    cfg = SearchConfig(width=args.width, n_n=150 if args.width <= 4 else 300,
                       evolve=EvolveConfig(generations=args.gens, lam=8,
                                           backend=args.backend))
    strategies = {
        "mae-only": [ConstraintSpec(mae=t) for t in (0.2, 0.5, 1.0, 2.0)],
        "er-only": [ConstraintSpec(er=t) for t in (20, 40, 60, 80)],
        "er+mae": [ConstraintSpec(er=e, mae=m)
                   for e in (30, 60) for m in (0.5, 2.0)],
    }
    results = {}
    for name, cons in strategies.items():
        ckpt = (f"{args.checkpoint_dir}/{name}" if args.checkpoint_dir
                else None)
        rdir = (f"{args.results_dir}/{name}" if args.results_dir else None)
        res = run_sweep_batched(
            cfg, cons, seeds=range(args.seeds),
            sweep=SweepConfig(chunk_size=args.chunk_size,
                              checkpoint_dir=ckpt, results_dir=rdir,
                              keep_history="summary" if rdir else "none"))
        # with --results-dir the records come back through the on-disk
        # shard reader — the same rows the in-RAM path returns
        recs = res.reader().records() if rdir else res.records
        results[name] = [r for r in recs if r.feasible]
        print(f"[{name}] {len(results[name])} feasible circuits "
              f"@ {res.runs_per_sec:.2f} runs/s"
              + (f" -> {len(res.reader().spans())} shards in {rdir}"
                 if rdir else ""))

    for metric, idx in (("MAE%", M.MAE), ("ER%", M.ER)):
        print(f"\n=== power vs {metric} Pareto fronts ===")
        for name, recs in results.items():
            pts = np.array([[r.power_rel, r.metrics[idx]] for r in recs])
            front = pareto_points(pts) if len(pts) else pts
            pretty = ", ".join(f"({p:.2f}, {m:.2f})" for p, m in front)
            print(f"{name:10s} {pretty}")


if __name__ == "__main__":
    main()

# Repo entry points (run from the repo root).
#   make test           — tier-1 suite (the ROADMAP verify command)
#   make test-fast      — tier-1 minus the slow multi-process tests
#   make bench-smoke    — quick benchmark pass: kernel micros + sweep engine
#   make bench-check    — tiny-budget bench pass gated against the committed
#                         baseline (what the CI bench-smoke job runs)
#   make bench-baseline — refresh benchmarks/bench_baseline.json (commit it)
#   make docs-check     — README/DESIGN link + §-reference + --help check
PY ?= python
export PYTHONPATH := src
BENCH_JSON ?= /tmp/BENCH_local.json

.PHONY: test test-fast bench-smoke bench-check bench-baseline docs-check

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench-smoke:
	$(PY) -m benchmarks.kernel_micro --only sweep,gen,results

bench-check:
	$(PY) -m benchmarks.kernel_micro --only sweep,gen,results --smoke \
		--json $(BENCH_JSON)
	$(PY) tools/check_bench.py $(BENCH_JSON)

bench-baseline:
	$(PY) -m benchmarks.kernel_micro --only sweep,gen,results --smoke \
		--json benchmarks/bench_baseline.json

docs-check:
	$(PY) tools/check_docs.py

# Repo entry points (run from the repo root).
#   make test        — tier-1 suite (the ROADMAP verify command)
#   make test-fast   — tier-1 minus the slow multi-process tests
#   make bench-smoke — quick benchmark pass: kernel micros + sweep engine
#   make docs-check  — README/DESIGN link + §-reference + --help check
PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast bench-smoke docs-check

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench-smoke:
	$(PY) benchmarks/kernel_micro.py --only sweep,gen,results

docs-check:
	$(PY) tools/check_docs.py

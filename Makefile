# Repo entry points (run from the repo root).
#   make test           — tier-1 suite (the ROADMAP verify command; pytest.ini
#                         deselects slow + kernel_diff legs by default)
#   make test-full      — everything, markers included (the CI tier1 job)
#   make test-fast      — alias of the tier-1 default
#   make bench-smoke    — quick benchmark pass: kernel micros + sweep engine
#   make bench-check    — tiny-budget bench pass gated against the committed
#                         baseline (what the CI bench-smoke job runs)
#   make bench-baseline — refresh benchmarks/bench_baseline.json (commit it)
#   make docs-check     — README/DESIGN link + §-reference + --help check
PY ?= python
export PYTHONPATH := src
BENCH_JSON ?= /tmp/BENCH_local.json

.PHONY: test test-full test-fast bench-smoke bench-check bench-baseline \
	docs-check

test:
	$(PY) -m pytest -x -q

test-full:
	$(PY) -m pytest -q -m ""

test-fast:
	$(PY) -m pytest -x -q -m "not slow and not kernel_diff"

bench-smoke:
	$(PY) -m benchmarks.kernel_micro --only sweep,gen,results,certify,lut

bench-check:
	$(PY) -m benchmarks.kernel_micro --only sweep,gen,results,certify,lut --smoke \
		--json $(BENCH_JSON)
	$(PY) tools/check_bench.py $(BENCH_JSON)

bench-baseline:
	$(PY) -m benchmarks.kernel_micro --only sweep,gen,results,certify,lut --smoke \
		--json benchmarks/bench_baseline.json

docs-check:
	$(PY) tools/check_docs.py
